"""Pallas kernel sweeps: shapes × dtypes vs the pure-jnp oracles (ref.py).

Kernels execute in interpret mode on CPU (TPU is the lowering target); the
sweep covers unaligned shapes (padding paths), both predicate directions,
and bf16/f32 inputs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("nq,nx,d", [(3, 5, 4), (17, 33, 7), (64, 128, 32),
                                     (100, 257, 96), (8, 1024, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l2dist_sweep(nq, nx, d, dtype):
    k1, k2 = jax.random.split(jax.random.key(nq * 1000 + nx))
    q = jax.random.normal(k1, (nq, d), dtype)
    x = jax.random.normal(k2, (nx, d), dtype)
    out = ops.pairwise_sq_dist(q, x)
    expect = ref.pairwise_sq_dist(q, x)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=tol, rtol=tol)


@pytest.mark.parametrize("nq,nx,d,k", [(5, 100, 8, 5), (13, 500, 24, 10),
                                       (32, 999, 16, 10), (4, 64, 8, 20)])
@pytest.mark.parametrize("is_filter", [True, False])
def test_fused_scan_sweep(nq, nx, d, k, is_filter):
    ks = jax.random.split(jax.random.key(nq + nx), 4)
    q = jax.random.normal(ks[0], (nq, d))
    x = jax.random.normal(ks[1], (nx, d))
    oi = jnp.sort(jax.random.uniform(ks[2], (nx, 2)), axis=1)
    c = jax.random.uniform(ks[3], (nq, 1))
    qi = jnp.concatenate([jnp.maximum(c - 0.35, 0), jnp.minimum(c + 0.35, 1)], axis=1)
    v, i = ops.filtered_topk(q, x, oi, qi, is_filter=is_filter, k=k)
    rv, ri = ref.filtered_topk(q, x, oi, qi, is_filter=is_filter, k=k)
    v_np, rv_np = np.asarray(v), np.asarray(rv)
    # values match where finite
    finite = np.isfinite(rv_np)
    np.testing.assert_allclose(
        np.where(finite, v_np, 0), np.where(finite, rv_np, 0), atol=1e-4
    )
    assert (np.isfinite(v_np) == finite).all()
    # id sets per row match (ties may permute)
    for r in range(nq):
        mine = set(int(a) for a, vv in zip(np.asarray(i)[r], v_np[r]) if np.isfinite(vv))
        theirs = set(int(a) for a, vv in zip(np.asarray(ri)[r], rv_np[r]) if np.isfinite(vv))
        assert mine == theirs


@pytest.mark.parametrize("B,M,n,d", [(2, 4, 50, 8), (9, 16, 200, 32),
                                     (1, 64, 1000, 128), (7, 33, 123, 17)])
def test_gather_dist_sweep(B, M, n, d):
    ks = jax.random.split(jax.random.key(B * M), 3)
    x = jax.random.normal(ks[0], (n, d))
    q = jax.random.normal(ks[1], (B, d))
    idx = jax.random.randint(ks[2], (B, M), -1, n)
    out = ops.gather_sq_dist(x, idx, q)
    expect = ref.gather_sq_dist(x, idx, q)
    finite = np.isfinite(np.asarray(expect))
    assert (np.isfinite(np.asarray(out)) == finite).all()
    np.testing.assert_allclose(
        np.where(finite, np.asarray(out), 0),
        np.where(finite, np.asarray(expect), 0), atol=1e-4,
    )


def test_fused_scan_is_exact_prefilter(small_corpus):
    """The fused kernel IS the paper's pre-filtering baseline: exact results."""
    from repro.core import intervals as iv
    from repro.core.search import brute_force

    x, ints = small_corpus
    k1, k2 = jax.random.split(jax.random.key(5))
    qv = jax.random.normal(k1, (10, x.shape[1]))
    c = jax.random.uniform(k2, (10, 1))
    qi = jnp.concatenate([jnp.maximum(c - 0.3, 0), jnp.minimum(c + 0.3, 1)], axis=1)
    v, i = ops.filtered_topk(qv, x, ints, qi, is_filter=True, k=10)
    gt = brute_force(x, ints, qv, qi, sem=iv.Semantics.IF, k=10)
    for r in range(10):
        mine = set(int(a) for a, vv in zip(np.asarray(i)[r], np.asarray(v)[r])
                   if np.isfinite(vv))
        theirs = set(int(a) for a in np.asarray(gt.ids)[r] if a >= 0)
        assert mine == theirs
