"""Recall regression harness for the unified index (ISSUE 1 acceptance).

On synthetic corpora every search pipeline — the legacy single-expansion
loop and both fused multi-expansion backends — must reach recall@10 ≥ 0.9
against brute force for each of the four semantics, and the two fused
backends must agree bit-for-bit on returned ids (same comparator network,
different lowering).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Semantics, UGConfig, UGIndex, recall
from repro.core import intervals as iv

EF = 96
K = 10
BACKENDS = ("legacy", "xla", "pallas")


@pytest.fixture(scope="module")
def interval_index(medium_corpus):
    """UG over uniform intervals — exercises IF / IS / RS."""
    x, ints = medium_corpus
    cfg = UGConfig(ef_spatial=32, ef_attribute=64, max_edges_if=32,
                   max_edges_is=32, iterations=3, repair_width=16,
                   exact_spatial=True, block=768)
    return UGIndex.build(x, ints, cfg)


@pytest.fixture(scope="module")
def point_index(medium_corpus):
    """UG over degenerate (point) object intervals — the RF special case."""
    x, _ = medium_corpus
    ints = iv.sample_point_intervals(jax.random.key(21), x.shape[0])
    cfg = UGConfig(ef_spatial=32, ef_attribute=64, max_edges_if=32,
                   max_edges_is=32, iterations=2, repair_width=16,
                   exact_spatial=True, block=768)
    return UGIndex.build(x, ints, cfg)


@pytest.fixture(scope="module")
def query_set(medium_corpus):
    x, _ = medium_corpus
    k1, k2 = jax.random.split(jax.random.key(31))
    nq = 32
    qv = jax.random.normal(k1, (nq, x.shape[1]))
    c = jax.random.uniform(k2, (nq, 1))
    window = jnp.concatenate(
        [jnp.maximum(c - 0.3, 0), jnp.minimum(c + 0.3, 1)], axis=1)
    point = jnp.concatenate([c, c], axis=1)
    return qv, window, point


def _cases(interval_index, point_index, query_set):
    qv, window, point = query_set
    return [
        (Semantics.IF, interval_index, qv, window),
        (Semantics.IS, interval_index, qv, window),
        (Semantics.RS, interval_index, qv, point),
        (Semantics.RF, point_index, qv, window),
    ]


@pytest.mark.parametrize("backend", BACKENDS)
def test_recall_at_10_all_semantics(backend, interval_index, point_index, query_set):
    for sem, idx, qv, qi in _cases(interval_index, point_index, query_set):
        res = idx.search(qv, qi, sem=sem, ef=EF, k=K, backend=backend)
        gt = idx.ground_truth(qv, qi, sem=sem, k=K)
        r = recall(res, gt)
        assert r >= 0.9, f"{sem} via {backend}: recall {r:.3f}"


def test_fused_backends_bitwise_identical(interval_index, point_index, query_set):
    """pallas (interpret) and xla run the same network: identical ids/dists."""
    for sem, idx, qv, qi in _cases(interval_index, point_index, query_set):
        rx = idx.search(qv, qi, sem=sem, ef=EF, k=K, backend="xla")
        rp = idx.search(qv, qi, sem=sem, ef=EF, k=K, backend="pallas")
        assert np.array_equal(np.asarray(rx.ids), np.asarray(rp.ids)), sem
        assert np.array_equal(np.asarray(rx.dist), np.asarray(rp.dist)), sem
        assert np.array_equal(np.asarray(rx.steps), np.asarray(rp.steps)), sem


def test_fused_results_satisfy_predicate(interval_index, query_set):
    """Fused search also never leaves the query-valid subgraph."""
    qv, window, point = query_set
    ints_np = np.asarray(interval_index.intervals)
    for sem, qi in [(Semantics.IF, window), (Semantics.IS, window),
                    (Semantics.RS, point)]:
        res = interval_index.search(qv, qi, sem=sem, ef=EF, k=K, backend="xla")
        ids = np.asarray(res.ids)
        qn = np.asarray(qi)
        for i in range(ids.shape[0]):
            for v in ids[i]:
                if v < 0:
                    continue
                ok = iv.predicate(sem, jnp.asarray(ints_np[v]), jnp.asarray(qn[i]))
                assert bool(ok), (sem, i, int(v))


@pytest.fixture(scope="module")
def per_backend_indexes(medium_corpus):
    """ISSUE 2: one UG build per prune backend, same key/config."""
    x, ints = medium_corpus
    out = {}
    for b in BACKENDS:
        cfg = UGConfig(ef_spatial=32, ef_attribute=64, max_edges_if=32,
                       max_edges_is=32, iterations=2, repair_width=16,
                       exact_spatial=True, block=768, prune_backend=b)
        out[b] = UGIndex.build(x, ints, cfg)
    return out


def test_per_backend_builds_identical_and_searchable(per_backend_indexes, query_set):
    """Every prune backend constructs the byte-identical graph, and the
    index it yields clears the recall floor (so the fused build path can
    never silently regress construction quality)."""
    qv, window, _ = query_set
    ref = per_backend_indexes["legacy"]
    for b in ("xla", "pallas"):
        idx = per_backend_indexes[b]
        assert np.array_equal(np.asarray(idx.graph.nbrs), np.asarray(ref.graph.nbrs)), b
        assert np.array_equal(np.asarray(idx.graph.status), np.asarray(ref.graph.status)), b
    for sem in (Semantics.IF, Semantics.IS):
        res = ref.search(qv, window, sem=sem, ef=EF, k=K)
        gt = ref.ground_truth(qv, window, sem=sem, k=K)
        assert recall(res, gt) >= 0.9, sem


def test_width_sweep_keeps_recall(interval_index, query_set):
    """Multi-expansion width trades steps for parallelism, not recall."""
    qv, window, _ = query_set
    gt = interval_index.ground_truth(qv, window, sem=Semantics.IF, k=K)
    for w in (0, 1, 2, 8):  # 0 clamps to 1 (entry batch included — regression)
        res = interval_index.search(
            qv, window, sem=Semantics.IF, ef=EF, k=K, backend="xla", width=w)
        assert recall(res, gt) >= 0.9, w
