"""Baseline indexes (paper §5.1 comparators) + HLO analysis unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import intervals as iv
from repro.core.baselines import HiPNGLite, PostFilterIndex, build_rrng, prefilter_search
from repro.core.build import UGConfig
from repro.core.entry import build_entry_index
from repro.core.index import UGIndex, recall
from repro.core.search import brute_force, search
from repro.core.store import make_store


CFG = UGConfig(ef_spatial=24, ef_attribute=48, max_edges_if=24, max_edges_is=24,
               iterations=2, repair_width=8, exact_spatial=True, block=768)


@pytest.fixture(scope="module")
def data():
    k1, k2, k3, k4 = jax.random.split(jax.random.key(21), 4)
    n, d, nq = 1200, 12, 24
    x = jax.random.normal(k1, (n, d))
    ints = iv.sample_uniform_intervals(k2, n)
    qv = jax.random.normal(k3, (nq, d))
    c = jax.random.uniform(k4, (nq, 1))
    qi = jnp.concatenate([jnp.maximum(c - 0.3, 0), jnp.minimum(c + 0.3, 1)], axis=1)
    return x, ints, qv, qi


def test_prefilter_is_exact(data):
    x, ints, qv, qi = data
    res = prefilter_search(x, ints, qv, qi, sem=iv.Semantics.IF, k=10)
    gt = brute_force(x, ints, qv, qi, sem=iv.Semantics.IF, k=10)
    assert recall(res, gt) == 1.0


def test_postfilter_baseline(data):
    """Post-filtering works but needs oversampling; results satisfy predicate."""
    x, ints, qv, qi = data
    idx = PostFilterIndex.build(x, ints, CFG)
    res = idx.search(qv, qi, sem=iv.Semantics.IF, ef=128, k=10, oversample=8)
    ints_np = np.asarray(ints)
    qn = np.asarray(qi)
    ids = np.asarray(res.ids)
    for i in range(ids.shape[0]):
        for v in ids[i]:
            if v >= 0:
                assert qn[i, 0] <= ints_np[v, 0] and ints_np[v, 1] <= qn[i, 1]
    gt = brute_force(x, ints, qv, qi, sem=iv.Semantics.IF, k=10)
    assert recall(res, gt) >= 0.3  # post-filtering recall is known-poor (§2.3)


def test_hipng_lite(data):
    x, ints, qv, qi = data
    hp = HiPNGLite.build(x, ints, depth=2, config=CFG)
    res = hp.search(qv, qi, ef=96, k=10)
    gt = brute_force(x, ints, qv, qi, sem=iv.Semantics.IF, k=10)
    assert recall(res, gt) >= 0.6


def test_rrng_scalar_special_case():
    """RRNG == UG with point intervals; RFANN queries answered on IF bits."""
    k1, k2, k3, k4 = jax.random.split(jax.random.key(5), 4)
    n, d = 800, 8
    x = jax.random.normal(k1, (n, d))
    scalars = jax.random.uniform(k2, (n,))
    g = build_rrng(jax.random.key(0), x, scalars, CFG)
    pts = jnp.stack([scalars, scalars], axis=1)
    eidx = build_entry_index(pts)
    qv = jax.random.normal(k3, (16, d))
    c = jax.random.uniform(k4, (16, 1))
    qi = jnp.concatenate([jnp.maximum(c - 0.3, 0), jnp.minimum(c + 0.3, 1)], axis=1)
    store = make_store(x, pts, g.nbrs, g.status, entry=eidx)
    res = search(store, qv, qi, sem=iv.Semantics.RF, ef=64, k=10)
    gt = brute_force(x, pts, qv, qi, sem=iv.Semantics.RF, k=10)
    assert recall(res, gt) >= 0.9


def test_ug_beats_postfilter(data):
    """The paper's headline: unified index >> post-filtering at equal ef."""
    x, ints, qv, qi = data
    ug = UGIndex.build(x, ints, CFG)
    pf = PostFilterIndex.build(x, ints, CFG)
    gt = brute_force(x, ints, qv, qi, sem=iv.Semantics.IF, k=10)
    r_ug = recall(ug.search(qv, qi, sem=iv.Semantics.IF, ef=64, k=10), gt)
    r_pf = recall(pf.search(qv, qi, sem=iv.Semantics.IF, ef=64, k=10, oversample=4), gt)
    assert r_ug > r_pf, (r_ug, r_pf)


# ----------------------------------------------------------------- HLO tools
def test_hlo_loop_weighting():
    """Collectives inside a 13-trip scan are weighted 13×."""
    import os

    from repro.launch.hlo_analysis import analyze_hlo
    from jax.sharding import NamedSharding, PartitionSpec as P

    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (covered by subprocess test)")


def test_hlo_parser_synthetic():
    from repro.launch.hlo_analysis import (_shape_bytes, collective_bytes,
                                           parse_computations)

    hlo = """
ENTRY %main.1 (p0: f32[8,16]) -> f32[8,16] {
  %p0 = f32[8,16]{1,0} parameter(0)
  %t = (s32[], f32[8,16]) tuple(%c0, %p0)
  %w = (s32[], f32[8,16]) while(%t), condition=%cond.1, body=%body.1
  ROOT %gte = f32[8,16]{1,0} get-tuple-element(%w), index=1
}
%body.1 (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %gte0 = f32[8,16]{1,0} get-tuple-element(%arg), index=1
  %ar = f32[8,16]{1,0} all-reduce(%gte0), to_apply=%add.1
  ROOT %tup = (s32[], f32[8,16]) tuple(%i, %ar)
}
%cond.1 (arg: (s32[], f32[8,16])) -> pred[] {
  %c = s32[] constant(11)
  ROOT %cmp = pred[] compare(%gi, %c), direction=LT
}
"""
    assert _shape_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    comps = parse_computations(hlo)
    assert set(comps) >= {"main.1", "body.1", "cond.1"}
    stats = collective_bytes(hlo)
    assert stats.total_bytes == 8 * 16 * 4 * 11
    assert stats.by_type["all-reduce"] == 8 * 16 * 4 * 11
