"""Chunked decayed linear attention vs the naive recurrence (DESIGN.md §5).

Covers both semantics (mamba-inclusive, rwkv-strict+bonus), odd lengths,
chunk-size sweeps, initial-state carry, and step-decode equivalence.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import chunked_linear_attention, linear_attention_step


def naive(q, k, v, w, bonus=None, inclusive=True, S0=None):
    B, S, H, Dk = q.shape
    Dv = v.shape[-1]
    st = np.zeros((B, H, Dk, Dv)) if S0 is None else np.asarray(S0, np.float64)
    out = np.zeros((B, S, H, Dv))
    q, k, v, w = (np.asarray(t, np.float64) for t in (q, k, v, w))
    for t in range(S):
        kv = k[:, t][..., :, None] * v[:, t][..., None, :]
        if inclusive:
            st = w[:, t][..., None] * st + kv
            out[:, t] = np.einsum("bhd,bhde->bhe", q[:, t], st)
        else:
            read = st + (bonus[None, ..., None] * kv if bonus is not None else 0)
            out[:, t] = np.einsum("bhd,bhde->bhe", q[:, t], read)
            st = w[:, t][..., None] * st + kv
    return out, st


def _data(seed, B=2, S=29, H=2, Dk=6, Dv=10, w_lo=0.6):
    ks = jax.random.split(jax.random.key(seed), 5)
    q = jax.random.normal(ks[0], (B, S, H, Dk))
    k = jax.random.normal(ks[1], (B, S, H, Dk))
    v = jax.random.normal(ks[2], (B, S, H, Dv))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, Dk))) * (0.98 - w_lo) + w_lo
    bonus = jax.random.normal(ks[4], (H, Dk)) * 0.5
    return q, k, v, w, bonus


@pytest.mark.parametrize("chunk", [1, 4, 7, 16, 64])
@pytest.mark.parametrize("inclusive", [True, False])
def test_chunked_matches_naive(chunk, inclusive):
    q, k, v, w, bonus = _data(chunk * 10 + inclusive)
    bn = None if inclusive else bonus
    o, Sf = chunked_linear_attention(
        q, k, v, jnp.log(w), bonus=bn, inclusive=inclusive, chunk=chunk
    )
    on, Sn = naive(q, k, v, w, None if bn is None else np.asarray(bn), inclusive)
    np.testing.assert_allclose(np.asarray(o), on, atol=2e-4)
    np.testing.assert_allclose(np.asarray(Sf), Sn, atol=2e-4)


def test_initial_state_carry():
    """Splitting a sequence across two chunked calls == one call."""
    q, k, v, w, _ = _data(7, S=24)
    lw = jnp.log(w)
    o_all, S_all = chunked_linear_attention(q, k, v, lw, inclusive=True, chunk=8)
    o1, S1 = chunked_linear_attention(
        q[:, :10], k[:, :10], v[:, :10], lw[:, :10], inclusive=True, chunk=8
    )
    o2, S2 = chunked_linear_attention(
        q[:, 10:], k[:, 10:], v[:, 10:], lw[:, 10:], inclusive=True, chunk=8,
        initial_state=S1,
    )
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([o1, o2], axis=1)), np.asarray(o_all), atol=2e-4
    )
    np.testing.assert_allclose(np.asarray(S2), np.asarray(S_all), atol=2e-4)


@pytest.mark.parametrize("inclusive", [True, False])
def test_step_decode_matches_chunked(inclusive):
    q, k, v, w, bonus = _data(3, S=13)
    bn = None if inclusive else bonus
    o_all, _ = chunked_linear_attention(
        q, k, v, jnp.log(w), bonus=bn, inclusive=inclusive, chunk=4
    )
    st = jnp.zeros((2, 2, 6, 10))
    outs = []
    for t in range(13):
        ot, st = linear_attention_step(
            q[:, t], k[:, t], v[:, t], w[:, t], st, bonus=bn, inclusive=inclusive
        )
        outs.append(ot)
    np.testing.assert_allclose(
        np.asarray(jnp.stack(outs, axis=1)), np.asarray(o_all), atol=2e-4
    )


def test_strong_decay_stability():
    """Aggressive decay (w ~ 0.05) with long chunks stays finite (log-space
    clamping; the k/P_i division is the classic overflow hazard)."""
    q, k, v, w, _ = _data(11, S=64, w_lo=0.05)
    o, Sf = chunked_linear_attention(q, k, v, jnp.log(w), inclusive=True, chunk=64)
    assert bool(jnp.isfinite(o).all()) and bool(jnp.isfinite(Sf).all())
