"""Shared fixtures: small deterministic corpora for the paper-core tests.

Also makes ``hypothesis`` optional: when the real package is unavailable the
vendored fallback (tests/_hypothesis_fallback.py) is registered under the
same module name *before* test modules import it, so the property-based
suites stay collectable and executable in hermetic environments.
"""
import sys

try:  # pragma: no cover - depends on environment
    import hypothesis  # noqa: F401
except ImportError:  # register the minimal vendored fallback
    import _hypothesis_fallback  # tests/ is on sys.path (pytest rootdir)

    sys.modules["hypothesis"] = _hypothesis_fallback
    sys.modules["hypothesis.strategies"] = _hypothesis_fallback.strategies

import jax
import jax.numpy as jnp
import pytest

from repro.core import intervals as iv


def pytest_addoption(parser):
    parser.addoption(
        "--run-slow", action="store_true", default=False,
        help="include tests marked slow (the heaviest hypothesis suites, "
             "excluded from the default tier-1 run to stay in CI budget)",
    )


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "hermetic: property/parity suites the no-hypothesis CI job runs "
        "(selected by marker — never by a hardcoded file list)",
    )
    config.addinivalue_line(
        "markers",
        "slow: heaviest property suites; skipped by default, run with "
        "--run-slow (an explicit -m selection also includes them)",
    )


def pytest_collection_modifyitems(config, items):
    # An explicit marker selection (-m hermetic, -m slow, ...) means the
    # caller chose their own slice — don't second-guess it.
    if config.getoption("--run-slow") or config.getoption("-m"):
        return
    skip = pytest.mark.skip(
        reason="slow suite: tier-2 by default, enable with --run-slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(scope="session")
def small_corpus():
    """(x, intervals) for exact-URNG scale tests (n=220, d=8)."""
    k1, k2 = jax.random.split(jax.random.key(0))
    n, d = 220, 8
    return jax.random.normal(k1, (n, d)), iv.sample_uniform_intervals(k2, n)


@pytest.fixture(scope="session")
def medium_corpus():
    """(x, intervals) for UG build tests (n=1500, d=16)."""
    k1, k2 = jax.random.split(jax.random.key(1))
    n, d = 1500, 16
    return jax.random.normal(k1, (n, d)), iv.sample_uniform_intervals(k2, n)


@pytest.fixture(scope="session")
def queries():
    """(q_v, q_intervals) — 40 queries with moderate windows (d=8)."""
    k1, k2 = jax.random.split(jax.random.key(2))
    nq = 40
    qv = jax.random.normal(k1, (nq, 8))
    c = jax.random.uniform(k2, (nq, 1))
    qi = jnp.concatenate([jnp.maximum(c - 0.3, 0), jnp.minimum(c + 0.3, 1)], axis=1)
    return qv, qi
