"""Model consistency: decode-vs-forward equivalence for every family, flash
attention vs naive softmax, MoE EP-vs-local numerics (single device)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, get_model
from repro.models import transformer as tr
from repro.models import rwkv_model as rm
from repro.models import zamba as zm
from repro.models.attention import decode_attention, flash_attention

F32 = dict(dtype=jnp.float32, remat=False)


def naive_attention(q, k, v, causal=True):
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    kh = jnp.repeat(k, g, axis=2)
    vh = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bshd->bhqs", q, kh) / jnp.sqrt(jnp.float32(hd))
    if causal:
        mask = jnp.tril(jnp.ones((Sq, k.shape[1]), bool))
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", p, vh)


@pytest.mark.parametrize("Sq,Sk,causal,qc,kc", [
    (16, 16, True, 4, 4), (32, 32, True, 16, 8), (8, 24, False, 4, 8),
    (33, 33, True, 7, 5),
])
def test_flash_vs_naive(Sq, Sk, causal, qc, kc):
    ks = jax.random.split(jax.random.key(0), 3)
    B, H, KV, hd = 2, 4, 2, 8
    q = jax.random.normal(ks[0], (B, Sq, H, hd))
    k = jax.random.normal(ks[1], (B, Sk, KV, hd))
    v = jax.random.normal(ks[2], (B, Sk, KV, hd))
    if causal and Sq != Sk:
        pytest.skip("naive ref assumes aligned causal")
    out = flash_attention(q, k, v, causal=causal, q_chunk=qc, kv_chunk=kc)
    expect = naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-4)


def test_flash_gqa_expand_semantics():
    """GQA head h attends to kv head h // (H/KV) — matches jnp.repeat."""
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 8, 8, 4))
    k = jax.random.normal(ks[1], (1, 8, 2, 4))
    v = jax.random.normal(ks[2], (1, 8, 2, 4))
    out = flash_attention(q, k, v, causal=True)
    expect = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-4)


def _decode_all(cfg, model, params, toks, fwd_fn):
    hid, _, _ = fwd_fn(cfg, params, toks)
    full = tr.unembed(cfg, params, hid)
    B, S = toks.shape
    if cfg.family == "decoder":
        state = tr.init_cache(cfg, B, S)
        step = tr.decode_step
    elif cfg.family == "rwkv6":
        state = rm.init_state(cfg, B)
        step = rm.decode_step
    else:
        state = zm.init_state(cfg, B, S)
        step = zm.decode_step
    outs = []
    for t in range(S):
        state, lg = step(cfg, params, state, toks[:, t : t + 1])
        outs.append(lg)
    return full, jnp.stack(outs, axis=1)


@pytest.mark.parametrize("name,kw,fwd", [
    ("gqa", dict(family="decoder", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                 d_ff=128, vocab=128), tr.forward),
    ("bias", dict(family="decoder", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                  d_ff=128, vocab=128, qkv_bias=True, qk_norm=True), tr.forward),
    ("mla", dict(family="decoder", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                 d_ff=128, vocab=128, mla=True, q_lora_rank=32, kv_lora_rank=16,
                 rope_head_dim=8, head_dim=16), tr.forward),
    ("moe-interleaved", dict(family="decoder", n_layers=4, d_model=64, n_heads=4,
                             n_kv_heads=2, d_ff=64, vocab=128, moe=True, n_experts=8,
                             top_k=1, moe_d_ff=64, dense_d_ff=128, moe_every=2,
                             capacity_factor=8.0), tr.forward),
    ("rwkv6", dict(family="rwkv6", n_layers=2, d_model=64, n_heads=4, d_ff=128,
                   vocab=128, ssm_chunk=8), rm.forward),
    ("zamba2", dict(family="zamba2", n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                    d_ff=128, vocab=128, ssm_state=16, ssm_chunk=8, attn_every=2),
     zm.forward),
])
def test_decode_matches_forward(name, kw, fwd):
    cfg = ModelConfig(**kw, **F32)
    model = get_model(cfg)
    params = model.init(jax.random.key(7))
    toks = jax.random.randint(jax.random.key(3), (2, 12), 0, cfg.vocab)
    full, inc = _decode_all(cfg, model, params, toks, fwd)
    err = float(jnp.max(jnp.abs(inc - full)))
    assert err < 5e-3, f"{name}: decode/forward mismatch {err}"


def test_encdec_decode_matches_train():
    from repro.models import encdec as ed

    cfg = ModelConfig(family="encdec", n_layers=2, enc_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab=128, **F32)
    model = get_model(cfg)
    params = model.init(jax.random.key(5))
    B, S = 2, 10
    frames = jax.random.normal(jax.random.key(6), (B, 6, cfg.d_model))
    toks = jax.random.randint(jax.random.key(7), (B, S), 0, cfg.vocab)
    enc_out = ed.encode(cfg, params, frames)
    hid = ed.decode_train(cfg, params, toks, enc_out)
    full = tr.unembed(cfg, params, hid)
    state = ed.init_state(cfg, params, frames, B, S)
    outs = []
    for t in range(S):
        state, lg = ed.decode_step(cfg, params, state, toks[:, t : t + 1])
        outs.append(lg)
    err = float(jnp.max(jnp.abs(jnp.stack(outs, 1) - full)))
    assert err < 5e-3, err


def test_chunked_ce_matches_full():
    cfg = ModelConfig(family="decoder", n_layers=1, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab=97, logits_chunk=5, **F32)
    model = get_model(cfg)
    params = model.init(jax.random.key(8))
    B, S = 3, 17
    hid = jax.random.normal(jax.random.key(9), (B, S, 32))
    labels = jax.random.randint(jax.random.key(10), (B, S), 0, 97)
    mask = (jax.random.uniform(jax.random.key(11), (B, S)) > 0.2).astype(jnp.float32)
    chunked = tr.lm_loss(cfg, params, hid, labels, mask)
    logits = tr.unembed(cfg, params, hid).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    full = jnp.sum((lse - gold) * mask) / jnp.sum(mask)
    np.testing.assert_allclose(float(chunked), float(full), rtol=1e-5)


def test_remat_does_not_change_loss():
    kw = dict(family="decoder", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
              d_ff=64, vocab=64, dtype=jnp.float32)
    b = {"tokens": jax.random.randint(jax.random.key(1), (2, 8), 0, 64),
         "labels": jax.random.randint(jax.random.key(2), (2, 8), 0, 64),
         "mask": jnp.ones((2, 8))}
    m1 = get_model(ModelConfig(**kw, remat=False))
    m2 = get_model(ModelConfig(**kw, remat=True))
    p = m1.init(jax.random.key(0))
    l1, _ = m1.loss(p, b)
    l2, _ = m2.loss(p, b)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    g1 = jax.grad(lambda pp: m1.loss(pp, b)[0])(p)
    g2 = jax.grad(lambda pp: m2.loss(pp, b)[0])(p)
    for a, c in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-5)
