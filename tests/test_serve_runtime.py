"""Async serve-runtime suite (DESIGN.md §13).

The continuous-batching contracts this pins:

* **exactness** — however the coalescer slices the request stream, every
  reply is bitwise-equal to a direct ``search_mixed`` call on the reply's
  pinned snapshot (row independence of the fused batch, DESIGN.md §10);
* **snapshot consistency** — a query admitted before a write answers
  against the pre-write snapshot, one admitted after against the post-write
  snapshot, never a torn mix (the writer swaps the index *reference*;
  readers pin it once at dequeue);
* **deadlines** — expired requests are answered with
  :class:`DeadlineExceeded` (at admission or at dequeue), never silently
  dropped, and they are counted in ``stats()["rejected"]``;
* **backpressure** — admission past ``max_queue`` raises
  :class:`QueueFull` synchronously;
* **single-sync upserts** — ``ServeEngine.upsert`` reads ``index.n``
  exactly once per call, and every chunk of every call lands on a
  :data:`~repro.serve.engine.BATCH_BUCKETS` shape.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FLAG_IF, FLAG_IS, Semantics, UGConfig, UGIndex
from repro.core import intervals as iv
from repro.core.search import search_mixed
from repro.serve import (
    DeadlineExceeded,
    QueueFull,
    RuntimeConfig,
    ServeEngine,
    ServeRuntime,
)
from repro.serve.engine import BATCH_BUCKETS, bucket_batch_size, upsert_chunk_plan

CFG = UGConfig(ef_spatial=16, ef_attribute=32, max_edges_if=12,
               max_edges_is=12, iterations=2, repair_width=8,
               exact_spatial=True, block=512)


_INDEX_CACHE: dict = {}


def small_index(n=300, d=12, seed=5):
    """Built once per (n, d, seed) and shared: the index is immutable (every
    update is functional and swaps the engine's *reference*), so engines in
    different tests can all attach the same snapshot safely."""
    key = (n, d, seed)
    if key not in _INDEX_CACHE:
        k1, k2 = jax.random.split(jax.random.key(seed))
        x = jax.random.normal(k1, (n, d))
        ints = iv.sample_uniform_intervals(k2, n)
        _INDEX_CACHE[key] = UGIndex.build(x, ints, CFG)
    return _INDEX_CACHE[key]


def make_engine(**kw):
    eng = ServeEngine(None, None)  # no model: q_v/x always precomputed here
    eng.attach_index(small_index(**kw))
    return eng


def make_queries(nq, d=12, seed=11):
    k1, k2 = jax.random.split(jax.random.key(seed))
    qv = jax.random.normal(k1, (nq, d))
    c = jax.random.uniform(k2, (nq, 1))
    qi = jnp.concatenate([jnp.maximum(c - 0.3, 0), jnp.minimum(c + 0.3, 1)],
                         axis=1)
    flags = [FLAG_IF if i % 2 else FLAG_IS for i in range(nq)]
    return qv, qi, flags


class FakeClock:
    """Injectable monotonic clock for deterministic deadline tests."""

    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def direct_rows(index, qv, qi, flags, *, ef=64, k=10, sel=None):
    """Reference answers: one padded search_mixed call per selected row set,
    exactly the engine's bucket-padding recipe."""
    idxs = list(range(qv.shape[0])) if sel is None else list(sel)
    B = len(idxs)
    q = jnp.stack([qv[i] for i in idxs])
    w = jnp.stack([qi[i] for i in idxs])
    f = jnp.asarray([flags[i] for i in idxs], jnp.int32)
    Bp = bucket_batch_size(B)
    if Bp != B:
        pad = Bp - B
        q = jnp.concatenate([q, jnp.zeros((pad, q.shape[1]), q.dtype)])
        w = jnp.concatenate(
            [w, jnp.broadcast_to(jnp.asarray([2.0, -2.0], w.dtype), (pad, 2))])
        f = jnp.concatenate([f, jnp.full((pad,), FLAG_IF, jnp.int32)])
    res = search_mixed(index.store, q, w, f, ef=ef, k=k)
    return np.asarray(res.ids)[:B], np.asarray(res.dist)[:B]


# ---------------------------------------------------------------- exactness
def test_inline_coalesced_results_match_direct_search():
    eng = make_engine()
    rt = ServeRuntime(eng)
    qv, qi, flags = make_queries(13)  # odd count: forces pad rows
    futs = [rt.submit(qv[i], qi[i], flags[i]) for i in range(13)]
    assert rt.run_until_idle() >= 1
    ids, dist = direct_rows(eng.index, qv, qi, flags)
    for i, f in enumerate(futs):
        rep = f.result(timeout=5)
        assert np.array_equal(rep.ids, ids[i])
        assert np.array_equal(rep.dist, dist[i])
        assert rep.index is eng.index
    assert rt.stats()["completed"] == 13


def test_mixed_compile_keys_split_into_exact_micro_batches():
    """Alternating (ef, k) breaks the stream into many tiny micro-batches;
    every reply must still equal the direct call on its own key."""
    eng = make_engine()
    rt = ServeRuntime(eng)
    qv, qi, flags = make_queries(12)
    keys = [(32, 5), (64, 10)]
    futs = [rt.submit(qv[i], qi[i], flags[i], ef=keys[i % 2][0],
                      k=keys[i % 2][1]) for i in range(12)]
    rt.run_until_idle()
    for (ef, k) in keys:
        sel = [i for i in range(12) if (keys[i % 2]) == (ef, k)]
        ids, dist = direct_rows(eng.index, qv, qi, flags, ef=ef, k=k, sel=sel)
        for j, i in enumerate(sel):
            rep = futs[i].result(timeout=5)
            assert rep.ids.shape == (k,)
            assert np.array_equal(rep.ids, ids[j])
            assert np.array_equal(rep.dist, dist[j])


def test_threaded_runtime_matches_direct_search():
    eng = make_engine()
    qv, qi, flags = make_queries(24)
    with ServeRuntime(eng, RuntimeConfig(max_batch=8)) as rt:
        futs = [rt.submit(qv[i], qi[i], flags[i]) for i in range(24)]
        reps = [f.result(timeout=30) for f in futs]
    ids, dist = direct_rows(eng.index, qv, qi, flags)
    for i, rep in enumerate(reps):
        assert np.array_equal(rep.ids, ids[i])
        assert np.array_equal(rep.dist, dist[i])
    s = rt.stats()
    assert s["completed"] == 24 and s["rejected"] == 0
    assert s["p99_ms"] >= s["p50_ms"] > 0


# ----------------------------------------------------- snapshot consistency
def test_no_torn_reads_across_a_write():
    """FIFO contract: queries before the remove answer the old snapshot,
    queries after answer the new one — each bitwise-equal to a direct
    search on the snapshot its reply pinned."""
    eng = make_engine()
    old_index = eng.index
    qv, qi, flags = make_queries(8)
    rt = ServeRuntime(eng)
    pre = [rt.submit(qv[i], qi[i], flags[i]) for i in range(8)]
    victim_ids = np.unique(np.concatenate(
        [direct_rows(old_index, qv, qi, flags)[0].ravel()]))
    victim_ids = victim_ids[victim_ids >= 0][:12]
    wfut = rt.submit_remove(jnp.asarray(victim_ids, jnp.int32))
    post = [rt.submit(qv[i], qi[i], flags[i]) for i in range(8)]
    rt.run_until_idle()

    assert wfut.result(timeout=5) == len(victim_ids)
    new_index = eng.index
    assert new_index is not old_index

    ids_old, dist_old = direct_rows(old_index, qv, qi, flags)
    ids_new, dist_new = direct_rows(new_index, qv, qi, flags)
    for i in range(8):
        a, b = pre[i].result(timeout=5), post[i].result(timeout=5)
        assert a.index is old_index and b.index is new_index
        assert np.array_equal(a.ids, ids_old[i])
        assert np.array_equal(a.dist, dist_old[i])
        assert np.array_equal(b.ids, ids_new[i])
        assert np.array_equal(b.dist, dist_new[i])
    # tombstoned docs never surface post-write
    gone = set(victim_ids.tolist())
    for i in range(8):
        assert not gone & set(post[i].result().ids.tolist())
    assert rt.stats()["writes"] == 1


def test_upsert_through_runtime_is_visible_to_later_queries():
    eng = make_engine(n=256)
    old_index = eng.index
    rt = ServeRuntime(eng)
    k1 = jax.random.key(99)
    xnew = jax.random.normal(k1, (16, 12))
    inew = jnp.broadcast_to(jnp.asarray([0.0, 1.0]), (16, 2))
    qv, qi, flags = make_queries(4)
    pre = [rt.submit(qv[i], qi[i], flags[i]) for i in range(4)]
    wfut = rt.submit_upsert(xnew, inew)
    post = [rt.submit(qv[i], qi[i], flags[i]) for i in range(4)]
    rt.run_until_idle()
    assert wfut.result(timeout=5) == 16
    assert eng.index is not old_index and eng.index.n == 256 + 16
    for i in range(4):
        assert pre[i].result().index is old_index
        assert post[i].result().index is eng.index


# ------------------------------------------------------ deadlines + bounds
def test_deadline_expired_at_admission_is_rejected():
    eng = make_engine()
    clk = FakeClock()
    rt = ServeRuntime(eng, clock=clk)
    qv, qi, flags = make_queries(1)
    fut = rt.submit(qv[0], qi[0], flags[0], deadline=clk() - 0.1)
    with pytest.raises(DeadlineExceeded):
        fut.result(timeout=1)
    assert rt.stats()["rejected"] == 1
    assert rt.run_until_idle() == 0  # nothing was enqueued


def test_deadline_expired_in_queue_is_rejected_not_dropped():
    eng = make_engine()
    clk = FakeClock()
    rt = ServeRuntime(eng, clock=clk)
    qv, qi, flags = make_queries(3)
    doomed = rt.submit(qv[0], qi[0], flags[0], deadline=clk() + 1.0)
    alive = [rt.submit(qv[i], qi[i], flags[i], deadline=clk() + 100.0)
             for i in (1, 2)]
    clk.advance(5.0)  # both queued; only the first expires
    rt.run_until_idle()
    with pytest.raises(DeadlineExceeded):
        doomed.result(timeout=1)
    ids, dist = direct_rows(eng.index, qv, qi, flags, sel=[1, 2])
    for j, f in enumerate(alive):
        assert np.array_equal(f.result(timeout=5).ids, ids[j])
    s = rt.stats()
    assert s["rejected"] == 1 and s["completed"] == 2


def test_admission_bound_raises_queue_full():
    eng = make_engine()
    rt = ServeRuntime(eng, RuntimeConfig(max_queue=2))
    qv, qi, flags = make_queries(3)
    rt.submit(qv[0], qi[0], flags[0])
    rt.submit(qv[1], qi[1], flags[1])
    with pytest.raises(QueueFull):
        rt.submit(qv[2], qi[2], flags[2])
    rt.run_until_idle()  # the two admitted requests still complete
    assert rt.stats()["completed"] == 2


def test_runtime_requires_an_attached_index():
    with pytest.raises(ValueError):
        ServeRuntime(ServeEngine(None, None))


# -------------------------------------------------- empty batches + chunks
def test_empty_batches_never_dispatch():
    eng = make_engine()
    assert eng.remove(jnp.zeros((0,), jnp.int32)) == 0
    assert eng.upsert(None, jnp.zeros((0, 2)), x=jnp.zeros((0, 12))) == 0
    res = eng.retrieve_mixed(None, jnp.zeros((0, 2)), [], k=7,
                             q_v=jnp.zeros((0, 12)))
    assert res.ids.shape == (0, 7) and res.dist.shape == (0, 7)
    with pytest.raises(ValueError):
        bucket_batch_size(0)
    with pytest.raises(ValueError):
        bucket_batch_size(-3)


def test_upsert_chunk_plan_shapes_and_coverage():
    for n_live, total in [(300, 16), (300, 500), (64, 1000), (10_000, 3000),
                          (0, 64), (5, 1)]:
        plan = upsert_chunk_plan(n_live, total)
        assert sum(plan) == total
        top = BATCH_BUCKETS[-1]
        for i, b in enumerate(plan[:-1]):  # the tail chunk may be a remnant
            assert b in BATCH_BUCKETS or b % top == 0, (n_live, total, plan)
        # chunk i never exceeds half the live count as of chunk i (floor 64)
        live = n_live
        for b in plan:
            assert b <= max(live // 2, 64)
            live += b
    assert upsert_chunk_plan(300, 0) == []


def test_upsert_reads_liveness_exactly_once(monkeypatch):
    eng = make_engine(n=256)
    calls = {"n": 0}
    orig = UGIndex.n.fget

    def counting_n(self):
        calls["n"] += 1
        return orig(self)

    monkeypatch.setattr(UGIndex, "n", property(counting_n))
    x = jax.random.normal(jax.random.key(3), (700, 12))
    ints = jnp.broadcast_to(jnp.asarray([0.0, 1.0]), (700, 2))
    assert eng.upsert(None, ints, x=x) == 700  # multiple chunks, one sync
    assert calls["n"] == 1


def test_runtime_writer_reuses_engine_chunk_plan(monkeypatch):
    """The runtime's writer path goes through ServeEngine.upsert and so
    inherits the single-sync chunk plan."""
    eng = make_engine(n=256)
    calls = {"n": 0}
    orig = UGIndex.n.fget

    def counting_n(self):
        calls["n"] += 1
        return orig(self)

    monkeypatch.setattr(UGIndex, "n", property(counting_n))
    rt = ServeRuntime(eng)
    x = jax.random.normal(jax.random.key(4), (400, 12))
    ints = jnp.broadcast_to(jnp.asarray([0.0, 1.0]), (400, 2))
    fut = rt.submit_upsert(x, ints)
    rt.run_until_idle()
    assert fut.result(timeout=5) == 400
    assert calls["n"] == 1


# ------------------------------------------------------- stats (ISSUE-7)
def test_pctl_nearest_rank_known_quantiles():
    """Nearest-rank percentile: index ceil(q*n)-1.  The old int(q*n) sat
    one rank high — the median of [1, 2] came back as 2."""
    from repro.serve.runtime import _pctl

    assert _pctl([], 0.5) == 0.0
    assert _pctl([7.0], 0.5) == 7.0
    assert _pctl([1.0, 2.0], 0.5) == 1.0          # the ISSUE-7 repro
    xs = [1.0, 2.0, 3.0, 4.0]
    assert _pctl(xs, 0.25) == 1.0
    assert _pctl(xs, 0.50) == 2.0
    assert _pctl(xs, 0.75) == 3.0
    assert _pctl(xs, 0.99) == 4.0
    assert _pctl(xs, 1.00) == 4.0
    hundred = [float(i) for i in range(1, 101)]
    assert _pctl(hundred, 0.50) == 50.0
    assert _pctl(hundred, 0.99) == 99.0
    assert _pctl(hundred, 0.999) == 100.0


def test_latency_reservoir_bounds_memory_and_samples_uniformly():
    from repro.serve.runtime import LatencyReservoir

    r = LatencyReservoir(100, seed=0)
    for i in range(10_000):
        r.offer(float(i))
    assert len(r) == 100 and r.seen == 10_000
    vals = sorted(r)
    assert all(0.0 <= v < 10_000 for v in vals)
    # a uniform sample of 0..9999 lands a near-uniform spread, not the head
    assert vals[0] < 2_000 and vals[-1] > 8_000
    # seeded: two identical streams hold identical samples
    r2 = LatencyReservoir(100, seed=0)
    r2.extend(float(i) for i in range(10_000))
    assert sorted(r2) == vals
    # below cap: verbatim
    r3 = LatencyReservoir(100)
    r3.extend([3.0, 1.0, 2.0])
    assert sorted(r3) == [1.0, 2.0, 3.0]
    with pytest.raises(ValueError):
        LatencyReservoir(0)


def test_runtime_latencies_are_bounded():
    rt = ServeRuntime(make_engine())
    from repro.serve.runtime import LatencyReservoir

    assert isinstance(rt._latencies, LatencyReservoir)


def test_stats_wall_clock_covers_active_windows_only():
    """ISSUE-7 satellite: qps must be measured over start/stop windows (and
    run_until_idle pumps), not since construction — idle time between
    cycles and pre-start build time must not dilute it."""
    eng = make_engine()
    clk = FakeClock()
    qv, qi, flags = make_queries(8)
    # warm the search_mixed compile cache on a throwaway runtime so the
    # timed cycles below never sit behind a cold XLA compile
    warm = ServeRuntime(eng, RuntimeConfig(max_batch=8))
    for i in range(8):
        warm.submit(qv[i], qi[i], flags[i])
    warm.run_until_idle()

    rt = ServeRuntime(eng, RuntimeConfig(max_batch=8), clock=clk)
    clk.advance(500.0)                 # idle before serving ever starts
    rt.start()
    futs = [rt.submit(qv[i], qi[i], flags[i]) for i in range(8)]
    for f in futs:
        f.result(timeout=120)
    clk.advance(2.0)                   # the only active wall time
    rt.stop()
    clk.advance(500.0)                 # idle after stop
    s = rt.stats()
    assert s["completed"] == 8
    assert s["qps"] == pytest.approx(8 / 2.0)

    # a second start/stop cycle extends the window, idle gaps still excluded
    rt.start()
    futs = [rt.submit(qv[i], qi[i], flags[i]) for i in range(8)]
    for f in futs:
        f.result(timeout=120)
    clk.advance(3.0)
    rt.stop()
    s = rt.stats()
    assert s["completed"] == 16
    assert s["qps"] == pytest.approx(16 / 5.0)


def test_stats_wall_clock_inline_mode():
    """Inline pumps count their own wall time; construction-to-run idle
    time does not leak into the qps denominator (the old behaviour made
    run_until_idle users report near-zero qps)."""
    eng = make_engine()
    clk = FakeClock()
    rt = ServeRuntime(eng, clock=clk)
    qv, qi, flags = make_queries(5)
    clk.advance(1000.0)                # idle: would dominate the old window
    for i in range(5):
        rt.submit(qv[i], qi[i], flags[i])
    rt.run_until_idle()
    s = rt.stats()
    assert s["completed"] == 5
    # the fake clock does not tick inside the pump, so the active window is
    # ~0 — any qps below completed/1s means idle time leaked in
    assert s["qps"] > 5.0
