"""On-device sharded build parity (DESIGN.md §12, ISSUE-5 acceptance).

Runs in a subprocess with 8 fake CPU devices (device count must be fixed
before jax initializes — same harness as test_distributed.py).

Asserts, in one subprocess to amortize the interpreter + build cost:

* the on-device ``build_sharded_store`` (ring-KNN bootstrap + shard-local
  attribute orders + the jitted prune/repair iterations under ``shard_map``)
  produces a sharded index whose search recall matches the serial host
  reference ``build_sharded_index_host`` within 0.01 on **all four**
  semantics (IF / IS / RS on uniform intervals, RF on point intervals);
* the device path never calls the host per-shard builder (``build_ug`` is
  stubbed to raise before the device build runs);
* an ``int8`` + rerank sharded store serves through the same search program
  within 0.02 recall of the f32 sharded store.
"""
from tests.test_distributed import run_sub


def test_device_build_matches_host_path():
    run_sub(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.core import intervals as iv, brute_force, recall
from repro.core.build import UGConfig
from repro.core.search import SearchResult
from repro.core import sharded as sh
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))
k1, k2, k3, k4 = jax.random.split(jax.random.key(0), 4)
n, d = 1200, 12
x = np.asarray(jax.random.normal(k1, (n, d)))
ints = np.asarray(iv.sample_uniform_intervals(k2, n))
pints = np.asarray(iv.sample_point_intervals(jax.random.fold_in(k2, 1), n))
cfg = UGConfig(ef_spatial=16, ef_attribute=32, max_edges_if=16, max_edges_is=16,
               iterations=2, repair_width=8, exact_spatial=True, block=512)

nq = 24
qv = jax.random.normal(k3, (nq, d))
c = jax.random.uniform(k4, (nq, 1))
wide = jnp.concatenate([jnp.maximum(c-0.3,0), jnp.minimum(c+0.3,1)], axis=1)
point = jnp.concatenate([c, c], axis=1)

# host reference path (the serial per-shard build_ug loop)
host_u = sh.shard_index(mesh, ("data",), *sh.build_sharded_index_host(x, ints, 4, cfg))
host_p = sh.shard_index(mesh, ("data",), *sh.build_sharded_index_host(x, pints, 4, cfg))

# device path must NEVER fall back to per-shard host builds: stub build_ug
import repro.core.build as build_mod
def _forbidden(*a, **k):
    raise AssertionError("on-device sharded build called host build_ug")
build_mod.build_ug = _forbidden

dev_u = sh.build_sharded_store(mesh, x, ints, cfg, index_axes=("data",))
dev_p = sh.build_sharded_store(mesh, x, pints, cfg, index_axes=("data",))

cases = [
    ("IF", iv.Semantics.IF, ints, wide, host_u, dev_u),
    ("IS", iv.Semantics.IS, ints, wide, host_u, dev_u),
    ("RS", iv.Semantics.RS, ints, point, host_u, dev_u),
    ("RF", iv.Semantics.RF, pints, wide, host_p, dev_p),
]
for name, sem, corpus_iv, qint, sidx_h, sidx_d in cases:
    fn = sh.make_sharded_search_fn(mesh, index_axes=("data",), sem=sem, ef=64, k=10)
    gt = brute_force(jnp.asarray(x), jnp.asarray(corpus_iv), qv, qint, sem=sem, k=10)
    r_host = recall(SearchResult(*fn(sidx_h, qv, qint), None), gt)
    r_dev = recall(SearchResult(*fn(sidx_d, qv, qint), None), gt)
    print(f"{name}: host {r_host:.3f} device {r_dev:.3f}")
    assert r_dev >= r_host - 0.01, (name, r_dev, r_host)

# int8 + rerank sharded store: same program family, quantized scan plane
dev_q8 = sh.build_sharded_store(mesh, x, ints, cfg, index_axes=("data",),
                                dtype="int8", rerank=True)
fn = sh.make_sharded_search_fn(mesh, index_axes=("data",), sem=iv.Semantics.IF,
                               ef=64, k=10)
fn8 = sh.make_sharded_search_fn(mesh, index_axes=("data",), sem=iv.Semantics.IF,
                                ef=64, k=10, plane_tag="int8", has_rerank=True)
gt = brute_force(jnp.asarray(x), jnp.asarray(ints), qv, wide, sem=iv.Semantics.IF, k=10)
r_f32 = recall(SearchResult(*fn(dev_u, qv, wide), None), gt)
r_q8 = recall(SearchResult(*fn8(dev_q8, qv, wide), None), gt)
print(f"int8+rerank: {r_q8:.3f} vs f32 {r_f32:.3f}")
assert r_q8 >= r_f32 - 0.02, (r_q8, r_f32)
assert dev_q8.store.plane.data.dtype == jnp.int8
assert dev_u.store.plane.bytes_per_vector() / dev_q8.store.plane.bytes_per_vector() >= 3.0
print("sharded device build parity OK")
""",
        timeout=1800,
    )
