"""Exact URNG reference: the paper's theoretical properties (§3).

* Thm 3.3  — monotonic searchability of each semantic projection;
* Thm 3.5  — structural heredity (induce == rebuild);
* Thm 4.1  — candidate-based pruning at M=∞ preserves heredity;
* Lemma A.2 — constant-factor degree overhead under the uniform model.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import intervals as iv
from repro.core.exact import DenseGraph, build_exact, greedy_monotonic_path


@pytest.fixture(scope="module")
def urng(small_corpus):
    x, ints = small_corpus
    return build_exact(x, ints, unified=True)


def _edge_set(g: DenseGraph, flag: int):
    nb, st = np.asarray(g.nbrs), np.asarray(g.status)
    out = set()
    for u in range(nb.shape[0]):
        for j in range(nb.shape[1]):
            if nb[u, j] >= 0 and (st[u, j] & flag):
                out.add((u, int(nb[u, j])))
    return out


def test_monotonic_searchability_if(urng, small_corpus):
    """Thm 3.3 (IF projection): greedy walk reaches ANY target — IF pruning
    always requires a witness, so the theorem holds unconditionally."""
    x, _ = small_corpus
    n = x.shape[0]
    rng = np.random.default_rng(0)
    for _ in range(25):
        s, t = rng.choice(n, size=2, replace=False)
        path = greedy_monotonic_path(urng, x, iv.Semantics.IF, int(s), int(t))
        assert path[-1] == int(t), f"IF: stuck at {path[-1]} != {t}"


def test_monotonic_searchability_is_on_valid_subgraphs(urng, small_corpus):
    """Thm 3.3 (IS projection) as search actually uses it: within any
    IS-query-valid subgraph, greedy walks reach every target.

    Alg. 3's empty-intersection shortcut (lines 7-8) clears IS bits of
    disjoint-interval pairs WITHOUT a witness, so global IS monotonicity
    can fail between disjoint nodes — but all nodes valid for one IS query
    pairwise overlap (they share q.I), and there the property holds.
    (Documented in DESIGN.md §6.)"""
    x, ints = small_corpus
    rng = np.random.default_rng(0)
    for window in [(0.45, 0.55), (0.3, 0.6), (0.48, 0.52)]:
        q = jnp.asarray(window, jnp.float32)
        mask = iv.query_valid_mask(iv.Semantics.IS, ints, q)
        valid = np.nonzero(np.asarray(mask))[0]
        if valid.size < 4:
            continue
        sub = urng.induced(mask)
        for _ in range(10):
            s, t = rng.choice(valid, size=2, replace=False)
            path = greedy_monotonic_path(sub, x, iv.Semantics.IS, int(s), int(t))
            assert path[-1] == int(t), f"IS[{window}]: stuck {path[-1]} != {t}"


@pytest.mark.parametrize("sem", [iv.Semantics.IF, iv.Semantics.IS])
@pytest.mark.parametrize("window", [(0.2, 0.8), (0.35, 0.65), (0.0, 1.0)])
def test_structural_heredity(urng, small_corpus, sem, window):
    """Thm 3.5: induced subgraph == URNG rebuilt on the valid node set."""
    x, ints = small_corpus
    q = jnp.asarray(window, jnp.float32)
    mask = iv.query_valid_mask(sem, ints, q)
    if int(mask.sum()) < 3:
        pytest.skip("degenerate window")
    rebuilt = build_exact(x, ints, unified=True, node_mask=np.asarray(mask))
    induced = urng.induced(mask)
    assert _edge_set(induced, sem.flag) == _edge_set(rebuilt, sem.flag)


def test_m_infinite_equivalence(small_corpus):
    """Thm 4.1 sanity: full-candidate prune == Def. 3.1 (same construction
    path is used; equivalence asserted via heredity on both semantics)."""
    x, ints = small_corpus
    g = build_exact(x, ints, unified=True)
    for sem in (iv.Semantics.IF, iv.Semantics.IS):
        q = jnp.asarray([0.25, 0.75], jnp.float32)
        mask = iv.query_valid_mask(sem, ints, q)
        rebuilt = build_exact(x, ints, unified=True, node_mask=np.asarray(mask))
        assert _edge_set(g.induced(mask), sem.flag) == _edge_set(rebuilt, sem.flag)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_heredity_holds_on_fused_sweep(urng, small_corpus, backend):
    """ISSUE 2: the fused (no-Φ-materialization) sweep still satisfies
    Def. 3.1 heredity — the exact URNG built through it equals the legacy
    graph bitwise, and induce == rebuild on a query-valid subset."""
    x, ints = small_corpus
    fused = build_exact(x, ints, unified=True, backend=backend)
    assert np.array_equal(np.asarray(fused.nbrs), np.asarray(urng.nbrs))
    assert np.array_equal(np.asarray(fused.status), np.asarray(urng.status))
    q = jnp.asarray([0.3, 0.7], jnp.float32)
    mask = iv.query_valid_mask(iv.Semantics.IF, ints, q)
    rebuilt = build_exact(x, ints, unified=True, node_mask=np.asarray(mask),
                          backend=backend)
    assert _edge_set(fused.induced(mask), iv.FLAG_IF) == \
        _edge_set(rebuilt, iv.FLAG_IF)


def test_classical_rng_is_subset_free(small_corpus):
    """URNG ≠ RNG (paper §3, 'no direct inclusion'): interval-aware pruning
    both *keeps* edges RNG drops (no valid witness) and *drops* edges RNG
    keeps (retained edges act as new witnesses)."""
    x, ints = small_corpus
    urng = build_exact(x, ints, unified=True)
    rng = build_exact(x, ints, unified=False)
    u_edges = _edge_set(urng, iv.FLAG_IF) | _edge_set(urng, iv.FLAG_IS)
    r_edges = _edge_set(rng, iv.FLAG_IF)
    assert u_edges - r_edges, "URNG should retain edges classical RNG prunes"


def test_degree_constant_factor(small_corpus):
    """Lemma A.2: mean URNG degree within a constant factor of RNG degree
    (theory bound C_urng = 6 + 13/3 per cone; we check a loose factor)."""
    x, ints = small_corpus
    urng = build_exact(x, ints, unified=True)
    rng = build_exact(x, ints, unified=False)
    d_u = float(
        (urng.degree(iv.FLAG_IF) + urng.degree(iv.FLAG_IS)).mean()
    )
    d_r = float(rng.degree(iv.FLAG_IF).mean())
    assert d_u <= (6 + 13 / 3) * d_r + 1e-6
    assert d_u >= d_r * 0.5  # not degenerately sparse either


def test_bitmask_cases_exist(urng):
    """All three live bitmask states occur (IF-only, IS-only, both) — the
    paper's Fig. 2 case analysis."""
    st = np.asarray(urng.status)
    nb = np.asarray(urng.nbrs)
    live = st[nb >= 0]
    states = set(int(s) for s in live)
    assert iv.FLAG_IF in states
    assert iv.FLAG_IS in states
    assert iv.FLAG_BOTH in states


def test_self_edges_absent(urng):
    nb = np.asarray(urng.nbrs)
    for u in range(nb.shape[0]):
        assert u not in set(nb[u][nb[u] >= 0].tolist())
