"""Streaming-update subsystem tests (DESIGN.md §11) + pipeline parallelism.

The acceptance contract of the update pipeline (ISSUE 4): after 10% delete
+ 10% insert churn on a synthetic build, recall@10 for all four semantics
stays within 0.02 of a from-scratch rebuild over the same live corpus; the
traced insert/delete/repair programs materialize no quadratic
intermediate; tombstoned nodes route but never surface; slots are reused
after delete→repair; and a mutated index survives both npz and ckpt-store
round trips with bitwise-identical search results.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Semantics, UGConfig, UGIndex, recall
from repro.core import intervals as iv
from repro.core.entry import get_entry_batch_flags
from repro.core.updates import insert, update_memory_profile

CHURN_CFG = UGConfig(ef_spatial=24, ef_attribute=48, max_edges_if=24,
                     max_edges_is=24, iterations=2, repair_width=8,
                     exact_spatial=True, block=512)
SMALL_CFG = UGConfig(ef_spatial=16, ef_attribute=32, max_edges_if=12,
                     max_edges_is=12, iterations=2, repair_width=8,
                     exact_spatial=True, block=256)


# --------------------------------------------------------------- fixtures
@pytest.fixture(scope="module")
def churn_data():
    """Corpus (800 base + 80 churn rows), deletion set, query workload."""
    k1, k2, k3, k4 = jax.random.split(jax.random.key(11), 4)
    n, extra, d = 800, 80, 12
    x_all = jax.random.normal(k1, (n + extra, d))
    iv_all = iv.sample_uniform_intervals(k2, n + extra)
    dels = jnp.asarray(
        np.random.default_rng(11).choice(n, size=extra, replace=False)
        .astype(np.int32)
    )
    qv = jax.random.normal(k3, (32, d))
    c = jax.random.uniform(k4, (32, 1))
    wide = jnp.concatenate(
        [jnp.maximum(c - 0.3, 0), jnp.minimum(c + 0.3, 1)], axis=1)
    point = jnp.concatenate([c, c], axis=1)
    return dict(n=n, extra=extra, x=x_all, iv=iv_all, dels=dels,
                qv=qv, wide=wide, point=point)


@pytest.fixture(scope="module")
def base_index(churn_data):
    n = churn_data["n"]
    return UGIndex.build(churn_data["x"][:n], churn_data["iv"][:n], CHURN_CFG)


@pytest.fixture(scope="module")
def deleted_index(base_index, churn_data):
    """10% delete: tombstone + iterative repair (slots become reusable)."""
    return base_index.delete(churn_data["dels"])


@pytest.fixture(scope="module")
def mutated_index(deleted_index, churn_data):
    """… then 10% insert; the batch reuses the repaired slots."""
    n = churn_data["n"]
    return deleted_index.insert(churn_data["x"][n:], churn_data["iv"][n:])


@pytest.fixture(scope="module")
def small_index():
    k1, k2 = jax.random.split(jax.random.key(5))
    n, d = 300, 10
    x = jax.random.normal(k1, (n, d))
    ints = iv.sample_uniform_intervals(k2, n)
    return UGIndex.build(x, ints, SMALL_CFG)


def _sem_cases(data):
    return [
        (Semantics.IF, data["wide"]), (Semantics.IS, data["wide"]),
        (Semantics.RS, data["point"]), (Semantics.RF, data["wide"]),
    ]


# ----------------------------------------------------- churn acceptance
def test_churn_recall_within_fresh_rebuild(mutated_index, churn_data):
    """ISSUE-4 acceptance: 10% delete + 10% insert churn stays within 0.02
    recall@10 of a from-scratch rebuild, for every semantics."""
    n = churn_data["n"]
    keep = np.setdiff1d(np.arange(n), np.asarray(churn_data["dels"]))
    x_f = jnp.concatenate([churn_data["x"][jnp.asarray(keep)],
                           churn_data["x"][n:]])
    iv_f = jnp.concatenate([churn_data["iv"][jnp.asarray(keep)],
                            churn_data["iv"][n:]])
    fresh = UGIndex.build(x_f, iv_f, CHURN_CFG)
    qv = churn_data["qv"]
    for sem, q in _sem_cases(churn_data):
        r_mut = recall(
            mutated_index.search(qv, q, sem=sem, ef=96, k=10),
            mutated_index.ground_truth(qv, q, sem=sem, k=10),
        )
        r_fresh = recall(
            fresh.search(qv, q, sem=sem, ef=96, k=10),
            fresh.ground_truth(qv, q, sem=sem, k=10),
        )
        assert r_mut >= r_fresh - 0.02, (
            f"{sem}: churned {r_mut:.3f} vs fresh rebuild {r_fresh:.3f}")


def test_churn_never_surfaces_deleted(deleted_index, mutated_index, churn_data):
    """Deleted nodes never surface; after the insert reuses their slots,
    every surfaced id is a live (reinserted or original) node."""
    dels = np.asarray(churn_data["dels"])
    for sem, q in _sem_cases(churn_data):
        res = deleted_index.search(churn_data["qv"], q, sem=sem, ef=96, k=10)
        ids = np.asarray(res.ids)
        assert not np.isin(ids[ids >= 0], dels).any(), sem
        res_m = mutated_index.search(churn_data["qv"], q, sem=sem, ef=96, k=10)
        ids_m = np.asarray(res_m.ids)
        alive = np.asarray(mutated_index.alive)
        assert alive[ids_m[ids_m >= 0]].all(), sem


def test_update_memory_profile():
    """Insert/delete/repair trace no (·,C,C) witness/dedup tensor and no
    (B,C,d) search/bridge gather; the pre-fusion legacy path shows both."""
    for backend in ("xla", "pallas"):
        prof = update_memory_profile(backend)
        assert not prof["quadratic_cc"], backend
        assert not prof["gather_bcd"], backend
    legacy = update_memory_profile("legacy")
    assert legacy["quadratic_cc"] and legacy["gather_bcd"]


# ------------------------------------------------------------ insert path
def test_incremental_insert(base_index, churn_data):
    """Inserted objects are findable; old recall is preserved; the PR-1
    ``insert`` wrapper still drives the batched pipeline."""
    n, extra = churn_data["n"], churn_data["extra"]
    idx = base_index
    idx2 = insert(idx, churn_data["x"][n:], churn_data["iv"][n:])
    assert idx2.n == n + extra
    assert idx2.capacity >= n + extra           # capacity-doubling allocator

    qv, qi = churn_data["qv"], churn_data["wide"]
    for sem in (Semantics.IF, Semantics.IS):
        # invariant: insertion preserves the pre-insert index's recall
        # (absolute recall at these small build params is corpus-dependent)
        r_before = recall(
            idx.search(qv, qi, sem=sem, ef=96, k=10),
            idx.ground_truth(qv, qi, sem=sem, k=10),
        )
        r = recall(
            idx2.search(qv, qi, sem=sem, ef=96, k=10),
            idx2.ground_truth(qv, qi, sem=sem, k=10),
        )
        assert r >= r_before - 0.05, f"{sem}: {r} vs pre-insert {r_before}"
    # degree budgets preserved after reverse-edge offers
    assert int(idx2.graph.degree(iv.FLAG_IF).max()) <= 24
    assert int(idx2.graph.degree(iv.FLAG_IS).max()) <= 24
    # an impossible-before query reaching ONLY new nodes
    new_hit = idx2.search(
        churn_data["x"][n:n + 1], jnp.asarray([[0.0, 1.0]]),
        sem=Semantics.IF, ef=64, k=1,
    )
    assert int(new_hit.ids[0, 0]) >= 0


def test_delete_then_reinsert_reuses_slot(small_index):
    """delete(repair=True) detaches the slot; the next insert reuses it
    (same physical slot id, new payload, old payload gone)."""
    idx = small_index
    victim = 17
    idx_d = idx.delete(jnp.asarray([victim]))
    assert idx_d.n == idx.n - 1
    assert bool(idx_d.free[victim]) and not bool(idx_d.alive[victim])
    new_v = jnp.ones((1, idx.x.shape[1])) * 0.25
    new_iv = jnp.asarray([[0.2, 0.8]])
    idx_r = idx_d.insert(new_v, new_iv)
    assert idx_r.capacity == idx.capacity      # no growth: slot reused
    assert bool(idx_r.alive[victim])
    assert np.allclose(np.asarray(idx_r.x[victim]), 0.25)
    hit = idx_r.search(new_v, jnp.asarray([[0.0, 1.0]]),
                       sem=Semantics.IF, ef=48, k=1)
    assert int(hit.ids[0, 0]) == victim


def test_delete_entire_interval_band(small_index):
    """Deleting every node valid under a window makes the window's IF
    queries NULL-certify (entry -1, all rows -1) — Lemma 4.3 with the
    tombstone-masked entry structure."""
    idx = small_index
    band = jnp.asarray([0.3, 0.7], jnp.float32)
    in_band = iv.contains(band[None, :], idx.intervals)
    dels = jnp.asarray(np.flatnonzero(np.asarray(in_band)).astype(np.int32))
    assert dels.size > 0
    idx_d = idx.delete(dels)
    q = jnp.asarray([[0.3, 0.7]], jnp.float32)
    qv = jnp.zeros((1, idx.x.shape[1]))
    res = idx_d.search(qv, q, sem=Semantics.IF, ef=48, k=10)
    assert int((np.asarray(res.ids) >= 0).sum()) == 0
    gt = idx_d.ground_truth(qv, q, sem=Semantics.IF, k=10)
    assert int((np.asarray(gt.ids) >= 0).sum()) == 0


def test_tombstoned_entry_points(small_index):
    """Alg. 5 over the rebuilt entry structure never certifies a tombstone,
    and surviving certificates stay valid (get_entry_batch_flags)."""
    idx = small_index
    nq = 24
    k1, k2 = jax.random.split(jax.random.key(9))
    c = jax.random.uniform(k1, (nq, 1))
    qints = jnp.concatenate(
        [jnp.maximum(c - 0.25, 0), jnp.minimum(c + 0.25, 1)], axis=1)
    flags = iv.as_sem_flags(
        [Semantics.IF, Semantics.IS] * (nq // 2), nq)
    ent0 = np.asarray(get_entry_batch_flags(idx.entry, qints, flags, width=4))
    victims = np.unique(ent0[ent0 >= 0])[:5].astype(np.int32)
    idx_d = idx.delete(jnp.asarray(victims), repair=False)
    ent1 = np.asarray(
        get_entry_batch_flags(idx_d.entry, qints, flags, width=4))
    assert not np.isin(ent1[ent1 >= 0], victims).any()
    # every certificate is genuinely valid for its query (Lemma 4.3)
    ivs = np.asarray(idx.intervals)
    qn = np.asarray(qints)
    fl = np.asarray(flags)
    for i in range(nq):
        for e in ent1[i]:
            if e < 0:
                continue
            if fl[i] == iv.FLAG_IF:
                assert qn[i, 0] <= ivs[e, 0] and ivs[e, 1] <= qn[i, 1]
            else:
                assert ivs[e, 0] <= qn[i, 0] and qn[i, 1] <= ivs[e, 1]


def test_tombstone_routes_but_never_surfaces(small_index):
    """repair=False leaves tombstones in the graph: search still reaches
    everything live (routing through dead nodes), but never returns one."""
    idx = small_index
    rng = np.random.default_rng(3)
    dels = jnp.asarray(rng.choice(idx.n, size=30, replace=False)
                       .astype(np.int32))
    idx_d = idx.delete(dels, repair=False)
    # tombstoned rows keep their edges (routing preserved) …
    assert int(jnp.sum(idx_d.graph.nbrs[dels] >= 0)) > 0
    # … and their slots are not yet reusable
    assert not bool(jnp.any(idx_d.free))
    k1, k2 = jax.random.split(jax.random.key(13))
    qv = jax.random.normal(k1, (16, idx.x.shape[1]))
    c = jax.random.uniform(k2, (16, 1))
    qi = jnp.concatenate(
        [jnp.maximum(c - 0.3, 0), jnp.minimum(c + 0.3, 1)], axis=1)
    for sem in (Semantics.IF, Semantics.IS):
        res = idx_d.search(qv, qi, sem=sem, ef=64, k=10)
        ids = np.asarray(res.ids)
        assert not np.isin(ids[ids >= 0], np.asarray(dels)).any()
        r = recall(res, idx_d.ground_truth(qv, qi, sem=sem, k=10))
        r0 = recall(idx.search(qv, qi, sem=sem, ef=64, k=10),
                    idx.ground_truth(qv, qi, sem=sem, k=10))
        assert r >= r0 - 0.1, f"{sem}: tombstoned {r} vs static {r0}"
    # a later repair detaches them and frees the slots
    from repro.core.updates import repair_deleted

    idx_r = repair_deleted(idx_d)
    assert int(jnp.sum(idx_r.free)) == dels.size
    assert int(jnp.sum(idx_r.graph.nbrs[dels] >= 0)) == 0


# --------------------------------------------------------- persistence
def _assert_same_search(a: UGIndex, b: UGIndex, nq=12):
    k1, k2 = jax.random.split(jax.random.key(21))
    qv = jax.random.normal(k1, (nq, a.x.shape[1]))
    c = jax.random.uniform(k2, (nq, 1))
    qi = jnp.concatenate(
        [jnp.maximum(c - 0.3, 0), jnp.minimum(c + 0.3, 1)], axis=1)
    for sem in (Semantics.IF, Semantics.IS):
        ra = a.search(qv, qi, sem=sem, ef=48, k=10)
        rb = b.search(qv, qi, sem=sem, ef=48, k=10)
        np.testing.assert_array_equal(np.asarray(ra.ids), np.asarray(rb.ids))
        np.testing.assert_array_equal(np.asarray(ra.dist), np.asarray(rb.dist))


@pytest.fixture(scope="module")
def small_mutated(small_index):
    rng = np.random.default_rng(1)
    dels = jnp.asarray(rng.choice(small_index.n, size=25, replace=False)
                       .astype(np.int32))
    k = jax.random.key(2)
    new_x = jax.random.normal(k, (10, small_index.x.shape[1]))
    new_iv = iv.sample_uniform_intervals(jax.random.fold_in(k, 1), 10)
    return small_index.delete(dels).insert(new_x, new_iv)


def test_ckpt_roundtrip_mutated_bitwise(small_mutated, tmp_path):
    """ckpt-store save → restore of a mutated index: allocator state and
    search results are bitwise identical (ISSUE-4 satellite)."""
    from repro.ckpt import restore_index, save_index

    save_index(tmp_path / "ck", 3, small_mutated)
    back = restore_index(tmp_path / "ck")
    assert back.capacity == small_mutated.capacity
    np.testing.assert_array_equal(
        np.asarray(back.alive), np.asarray(small_mutated.alive))
    np.testing.assert_array_equal(
        np.asarray(back.free), np.asarray(small_mutated.free))
    _assert_same_search(small_mutated, back)


def test_npz_roundtrip_mutated_bitwise(small_mutated, tmp_path):
    small_mutated.save(tmp_path / "idx")
    back = UGIndex.load(tmp_path / "idx")
    assert back.n == small_mutated.n
    _assert_same_search(small_mutated, back)


def test_compact_repairs_deferred_tombstones(small_index):
    """compact() after delete(repair=False) must run the repair sweep first
    — dropping routable tombstones without bridging would sever paths."""
    rng = np.random.default_rng(8)
    dels = jnp.asarray(rng.choice(small_index.n, size=30, replace=False)
                       .astype(np.int32))
    a = small_index.delete(dels, repair=True).compact()
    b = small_index.delete(dels, repair=False).compact()
    np.testing.assert_array_equal(
        np.asarray(a.graph.nbrs), np.asarray(b.graph.nbrs))
    np.testing.assert_array_equal(
        np.asarray(a.graph.status), np.asarray(b.graph.status))


def test_compact_preserves_answers(small_mutated):
    """compact() drops dead slots and remaps ids: same answers, smaller
    arrays, static (mask-free) layout."""
    comp = small_mutated.compact()
    assert comp.alive is None and comp.capacity == small_mutated.n
    k1, k2 = jax.random.split(jax.random.key(33))
    qv = jax.random.normal(k1, (12, comp.x.shape[1]))
    c = jax.random.uniform(k2, (12, 1))
    qi = jnp.concatenate(
        [jnp.maximum(c - 0.3, 0), jnp.minimum(c + 0.3, 1)], axis=1)
    # remap old ids -> compacted ids to compare answer sets
    live = np.asarray(small_mutated.alive)
    remap = np.full((small_mutated.capacity,), -1, np.int64)
    remap[np.flatnonzero(live)] = np.arange(live.sum())
    for sem in (Semantics.IF, Semantics.IS):
        r_old = small_mutated.search(qv, qi, sem=sem, ef=48, k=10)
        r_new = comp.search(qv, qi, sem=sem, ef=48, k=10)
        ids_old = np.asarray(r_old.ids)
        mapped = np.where(ids_old >= 0, remap[np.clip(ids_old, 0, None)], -1)
        for row_m, row_n in zip(mapped, np.asarray(r_new.ids)):
            assert set(row_m[row_m >= 0]) == set(row_n[row_n >= 0]), sem


# ------------------------------------------------------------- serving
def test_engine_upsert_remove_bucketing(small_index):
    """ServeEngine streaming path: bucketed upsert/remove keep the index
    consistent; pad rows allocate nothing and are reclaimed next insert."""
    from repro.serve.engine import ServeEngine

    engine = ServeEngine.__new__(ServeEngine)   # no LM tower needed here
    engine.index = None
    engine.search_backend = "xla"
    engine.search_width = 4
    engine.attach_index(small_index)
    n0 = small_index.n

    k = jax.random.key(41)
    new_x = jax.random.normal(k, (5, small_index.x.shape[1]))
    new_iv = iv.sample_uniform_intervals(jax.random.fold_in(k, 1), 5)
    engine.upsert(None, new_iv, x=new_x)        # pads 5 -> bucket of 8
    assert engine.index.n == n0 + 5
    engine.remove(jnp.arange(3, dtype=jnp.int32))
    assert engine.index.n == n0 + 5 - 3
    res = engine.retrieve(None, jnp.asarray([[0.0, 1.0]] * 5),
                          sem=Semantics.IF, ef=48, k=5, q_v=new_x)
    ids = np.asarray(res.ids)
    assert not np.isin(ids[ids >= 0], [0, 1, 2]).any()
    # pad slots from the bucketed upsert are free for the next batch
    assert engine.index.capacity >= n0 + 8


def test_pipeline_forward_subprocess():
    """GPipe pipeline == sequential stack (8 fake devices, subprocess)."""
    from tests.test_distributed import run_sub

    run_sub(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import pipeline_forward, bubble_fraction
from repro.launch.mesh import make_mesh

mesh = make_mesh((4,), ("stage",))
n_stages, per, d = 4, 2, 16
key = jax.random.key(0)
Ws = jax.random.normal(key, (n_stages, per, d, d)) * (1.0 / d ** 0.5)

def stage_fn(p, x):
    for i in range(per):
        x = jnp.tanh(x @ p[i])
    return x

x = jax.random.normal(jax.random.fold_in(key, 1), (8, 4, d))  # 8 microbatches
out = pipeline_forward(mesh, "stage", stage_fn, Ws, x)

ref = x
for s in range(n_stages):
    ref = jax.vmap(lambda mb: stage_fn(Ws[s], mb))(ref)
err = float(jnp.max(jnp.abs(out - ref)))
assert err < 1e-5, err
assert abs(bubble_fraction(8, 4) - 3/11) < 1e-9
print("pipeline OK", err)
"""
    )
