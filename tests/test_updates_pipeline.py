"""Incremental index insertion + pipeline parallelism tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Semantics, UGConfig, UGIndex, recall
from repro.core import intervals as iv
from repro.core.updates import insert


def test_incremental_insert():
    """Inserted objects are findable; old recall is preserved."""
    k1, k2, k3, k4 = jax.random.split(jax.random.key(31), 4)
    n, d = 800, 12
    x = jax.random.normal(k1, (n + 50, d))
    ints = iv.sample_uniform_intervals(k2, n + 50)
    cfg = UGConfig(ef_spatial=24, ef_attribute=48, max_edges_if=24,
                   max_edges_is=24, iterations=2, repair_width=8,
                   exact_spatial=True, block=512)
    idx = UGIndex.build(x[:n], ints[:n], cfg)
    idx2 = insert(idx, x[n:], ints[n:])
    assert idx2.n == n + 50

    qv = jax.random.normal(k3, (24, d))
    c = jax.random.uniform(k4, (24, 1))
    qi = jnp.concatenate([jnp.maximum(c - 0.3, 0), jnp.minimum(c + 0.3, 1)], axis=1)
    for sem in (Semantics.IF, Semantics.IS):
        # invariant: insertion preserves the pre-insert index's recall
        # (absolute recall at these small build params is corpus-dependent)
        r_before = recall(
            idx.search(qv, qi, sem=sem, ef=96, k=10),
            idx.ground_truth(qv, qi, sem=sem, k=10),
        )
        res = idx2.search(qv, qi, sem=sem, ef=96, k=10)
        gt = idx2.ground_truth(qv, qi, sem=sem, k=10)
        r = recall(res, gt)
        assert r >= r_before - 0.05, f"{sem}: {r} vs pre-insert {r_before}"
    # degree budgets preserved after reverse-edge repair
    assert int(idx2.graph.degree(iv.FLAG_IF).max()) <= 24
    assert int(idx2.graph.degree(iv.FLAG_IS).max()) <= 24
    # an impossible-before query reaching ONLY new nodes
    new_hit = idx2.search(x[n:n+1], jnp.asarray([[0.0, 1.0]]), sem=Semantics.IF,
                          ef=64, k=1)
    assert int(new_hit.ids[0, 0]) >= 0


def test_pipeline_forward_subprocess():
    """GPipe pipeline == sequential stack (8 fake devices, subprocess)."""
    from tests.test_distributed import run_sub

    run_sub(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import pipeline_forward, bubble_fraction
from repro.launch.mesh import make_mesh

mesh = make_mesh((4,), ("stage",))
n_stages, per, d = 4, 2, 16
key = jax.random.key(0)
Ws = jax.random.normal(key, (n_stages, per, d, d)) * (1.0 / d ** 0.5)

def stage_fn(p, x):
    for i in range(per):
        x = jnp.tanh(x @ p[i])
    return x

x = jax.random.normal(jax.random.fold_in(key, 1), (8, 4, d))  # 8 microbatches
out = pipeline_forward(mesh, "stage", stage_fn, Ws, x)

ref = x
for s in range(n_stages):
    ref = jax.vmap(lambda mb: stage_fn(Ws[s], mb))(ref)
err = float(jnp.max(jnp.abs(out - ref)))
assert err < 1e-5, err
assert abs(bubble_fraction(8, 4) - 3/11) < 1e-9
print("pipeline OK", err)
"""
    )
