"""IndexStore / vector-plane suite (DESIGN.md §12, ISSUE-5 acceptance).

Pins the unified-store contracts:

* **buffer identity** — an f32 index's ``x`` view IS the plane buffer, and
  a ServeEngine holds the attached store by reference (zero duplicate
  device copies across attach + retrieve);
* **cross-dtype parity** — ``bf16``/``int8`` scan planes on the *same
  graph* stay within tolerance of the f32 plane, and ``int8`` + the f32
  rerank plane matches the f32 top-k quality (≤ 0.02 recall loss);
* **quantized kernels** — the int8 expand-score Pallas kernel and its XLA
  twin are bit-identical and chunk-invariant, and the traced search step
  materializes no ``(B, C, d)`` gather on the quantized plane either;
* **persistence** — npz and ckpt-store round trips preserve quantization
  parameters and codes bitwise (codes are meaningless under any other
  scale/zero).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Semantics, UGConfig, UGIndex, recall
from repro.core import intervals as iv
from repro.core.store import (
    PQ_K, VectorPlane, default_pq_m, quantization_params,
    train_pq_codebooks,
)
from repro.kernels import ops

pytestmark = pytest.mark.hermetic  # parity suite for the no-hypothesis job

CFG = UGConfig(ef_spatial=16, ef_attribute=32, max_edges_if=12,
               max_edges_is=12, iterations=2, repair_width=8,
               exact_spatial=True, block=256)


@pytest.fixture(scope="module")
def plane_index():
    k1, k2 = jax.random.split(jax.random.key(3))
    n, d = 360, 12
    x = jax.random.normal(k1, (n, d))
    ints = iv.sample_uniform_intervals(k2, n)
    return UGIndex.build(x, ints, CFG)


@pytest.fixture(scope="module")
def plane_queries(plane_index):
    k1, k2 = jax.random.split(jax.random.key(13))
    nq = 24
    qv = jax.random.normal(k1, (nq, plane_index.store.dim))
    c = jax.random.uniform(k2, (nq, 1))
    qi = jnp.concatenate(
        [jnp.maximum(c - 0.3, 0), jnp.minimum(c + 0.3, 1)], axis=1)
    return qv, qi


# ------------------------------------------------------------ store basics
def test_f32_plane_is_identity_view(plane_index):
    """For an f32 plane, ``UGIndex.x`` and ``plane.decode()`` are the SAME
    buffer — no copy anywhere on the static path."""
    st = plane_index.store
    assert st.plane.tag == "f32"
    assert plane_index.x is st.plane.data
    assert st.plane.decode() is st.plane.data
    assert st.vectors_f32() is st.plane.data


def test_quantization_roundtrip_error_bound(plane_index):
    x = plane_index.x
    plane = VectorPlane.encode(x, "int8")
    err = jnp.abs(plane.decode() - x)
    # affine per-dim quantization: |err| <= scale/2 (+ float slop)
    assert bool(jnp.all(err <= plane.scale[None, :] * 0.5 + 1e-6))
    # frozen-parameter row encoding matches full-plane encoding bitwise
    rows = plane.encode_rows(x[:7])
    np.testing.assert_array_equal(np.asarray(rows), np.asarray(plane.data[:7]))


def test_plane_bytes_per_vector(plane_index):
    d = plane_index.store.dim
    f32 = plane_index.store.plane.bytes_per_vector()
    bf16 = VectorPlane.encode(plane_index.x, "bf16").bytes_per_vector()
    q8 = VectorPlane.encode(plane_index.x, "int8").bytes_per_vector()
    assert f32 == 4 * d
    assert bf16 == 2 * d
    assert f32 / q8 >= 3.0  # the ISSUE-5 ≥3x scan-bytes reduction


# --------------------------------------------------------- cross-dtype parity
def test_cross_dtype_recall_parity(plane_index, plane_queries):
    """bf16 / int8 planes on the same graph stay near the f32 plane; int8 +
    f32 rerank stays within 0.02 of f32 (the ISSUE-5 acceptance bound)."""
    qv, qi = plane_queries
    for sem in (Semantics.IF, Semantics.RS):
        q = qi if sem is Semantics.IF else jnp.concatenate(
            [qi[:, :1], qi[:, :1]], axis=1)
        gt = plane_index.ground_truth(qv, q, sem=sem, k=10)
        r_f32 = recall(plane_index.search(qv, q, sem=sem, ef=64, k=10), gt)
        r_bf16 = recall(
            plane_index.with_dtype("bf16").search(qv, q, sem=sem, ef=64, k=10),
            gt)
        r_q8rr = recall(
            plane_index.with_dtype("int8", rerank=True)
            .search(qv, q, sem=sem, ef=64, k=10), gt)
        assert r_bf16 >= r_f32 - 0.05, (sem, r_bf16, r_f32)
        assert r_q8rr >= r_f32 - 0.02, (sem, r_q8rr, r_f32)


def test_int8_without_rerank_still_searches(plane_index, plane_queries):
    qv, qi = plane_queries
    idx8 = plane_index.with_dtype("int8", rerank=False)
    assert idx8.store.rerank is None
    gt = plane_index.ground_truth(qv, qi, sem=Semantics.IF, k=10)
    r = recall(idx8.search(qv, qi, sem=Semantics.IF, ef=64, k=10), gt)
    r_f32 = recall(plane_index.search(qv, qi, sem=Semantics.IF, ef=64, k=10), gt)
    assert r >= r_f32 - 0.1, (r, r_f32)


# ------------------------------------------------------------ int8 kernels
def test_expand_score_q_backends_bitwise():
    k1, k2, k3 = jax.random.split(jax.random.key(7), 3)
    n, d, B, C = 257, 19, 6, 23
    x = jax.random.normal(k1, (n, d))
    plane = VectorPlane.encode(x, "int8")
    q = jax.random.normal(k2, (B, d))
    idx = jax.random.randint(k3, (B, C), -2, n)
    outs = {
        b: np.asarray(ops.expand_score_plane(plane, idx, q, backend=b))
        for b in ("pallas", "xla")
    }
    np.testing.assert_array_equal(outs["pallas"], outs["xla"])
    assert np.isinf(outs["xla"][np.asarray(idx) < 0]).all()
    # chunk invariance of the xla twin (elementwise reduction contract)
    from repro.kernels.expand_score import expand_score_q_xla

    for chunk in (1, 5, 11):
        np.testing.assert_array_equal(
            np.asarray(expand_score_q_xla(
                plane.data, plane.scale, plane.zero, idx, q, chunk=chunk)),
            outs["xla"])
    # legacy agrees numerically (matmul identity: allclose only)
    legacy = np.asarray(ops.expand_score_plane(plane, idx, q, backend="legacy"))
    fin = np.isfinite(outs["xla"])
    np.testing.assert_allclose(legacy[fin], outs["xla"][fin], atol=1e-3)


def test_search_step_profile_int8():
    """The quantized plane carries the same traced-memory guarantee: no
    (B, C, d) gather, no (·, C, C) dedup tensor (DESIGN.md §12)."""
    from repro.core.search import search_step_memory_profile

    for backend in ("xla", "pallas"):
        prof = search_step_memory_profile(backend, dtype="int8")
        assert not prof["gather_bcd"], backend
        assert not prof["quadratic_cc"], backend
    legacy = search_step_memory_profile("legacy", dtype="int8")
    assert legacy["gather_bcd"] and legacy["quadratic_cc"]


def test_mixed_search_on_quantized_plane(plane_index, plane_queries):
    """Runtime-semantics batches work unchanged on a quantized store."""
    qv, qi = plane_queries
    idx8 = plane_index.with_dtype("int8", rerank=True)
    sems = [Semantics.IF, Semantics.IS] * (qv.shape[0] // 2)
    res = idx8.search_mixed(qv, qi, sems, ef=48, k=10)
    for s in (Semantics.IF, Semantics.IS):
        sel = np.asarray([i for i, ss in enumerate(sems) if ss is s])
        ref = idx8.search(qv[sel], qi[sel], sem=s, ef=48, k=10)
        np.testing.assert_array_equal(
            np.asarray(res.ids)[sel], np.asarray(ref.ids))


# ------------------------------------------------------------- persistence
def _assert_store_bitwise(a, b):
    np.testing.assert_array_equal(np.asarray(a.plane.data),
                                  np.asarray(b.plane.data))
    assert a.plane.tag == b.plane.tag
    for f in ("scale", "zero", "codebooks"):
        av, bv = getattr(a.plane, f), getattr(b.plane, f)
        assert (av is None) == (bv is None)
        if av is not None:
            np.testing.assert_array_equal(np.asarray(av), np.asarray(bv))
    assert (a.rerank is None) == (b.rerank is None)
    if a.rerank is not None:
        np.testing.assert_array_equal(np.asarray(a.rerank.data),
                                      np.asarray(b.rerank.data))


def test_npz_roundtrip_preserves_quantization_bitwise(plane_index, plane_queries, tmp_path):
    idx8 = plane_index.with_dtype("int8", rerank=True)
    idx8.save(tmp_path / "q")
    back = UGIndex.load(tmp_path / "q")
    _assert_store_bitwise(idx8.store, back.store)
    qv, qi = plane_queries
    ra = idx8.search(qv, qi, sem=Semantics.IS, ef=48, k=10)
    rb = back.search(qv, qi, sem=Semantics.IS, ef=48, k=10)
    np.testing.assert_array_equal(np.asarray(ra.ids), np.asarray(rb.ids))
    np.testing.assert_array_equal(np.asarray(ra.dist), np.asarray(rb.dist))


def test_ckpt_roundtrip_preserves_quantization_bitwise(plane_index, tmp_path):
    from repro.ckpt import restore_index, save_index

    idx8 = plane_index.with_dtype("int8", rerank=True)
    save_index(tmp_path / "ck", 1, idx8)
    back = restore_index(tmp_path / "ck")
    _assert_store_bitwise(idx8.store, back.store)
    assert back.dtype == "int8"


def test_bf16_roundtrips_npz_and_ckpt(plane_index, tmp_path):
    """bf16 codes survive both persistence paths bitwise (numpy cannot
    serialize ml_dtypes bfloat16 natively — stored as a uint16 bit view)."""
    from repro.ckpt import restore_index, save_index

    idxb = plane_index.with_dtype("bf16")
    idxb.save(tmp_path / "npz")
    back = UGIndex.load(tmp_path / "npz")
    assert back.dtype == "bf16"
    assert back.store.plane.data.dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(idxb.store.plane.data).view(np.uint16),
        np.asarray(back.store.plane.data).view(np.uint16))
    save_index(tmp_path / "ck", 2, idxb)
    back2 = restore_index(tmp_path / "ck")
    assert back2.store.plane.data.dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(idxb.store.plane.data).view(np.uint16),
        np.asarray(back2.store.plane.data).view(np.uint16))


def test_shard_index_qparams_ignore_pad_rows(plane_index):
    """Host-assembled sharded stores derive int8 params from real rows only
    — the builder's zero pad rows must not widen the per-dim ranges."""
    from jax.sharding import Mesh
    from repro.core.sharded import shard_index

    x = np.asarray(plane_index.x) + 5.0          # offset: 0-pads are outliers
    n, d = x.shape
    ints = np.asarray(plane_index.intervals)
    nbrs = np.asarray(plane_index.store.nbrs)
    stat = np.asarray(plane_index.store.status)
    # append one zero pad row (gid = -1), as build_sharded_index_host does
    xp = np.concatenate([x, np.zeros((1, d), x.dtype)])
    ip = np.concatenate([ints, np.asarray([[2.0, -2.0]], ints.dtype)])
    nbp = np.concatenate([nbrs, np.full((1, nbrs.shape[1]), -1, nbrs.dtype)])
    stp = np.concatenate([stat, np.zeros((1, stat.shape[1]), stat.dtype)])
    gid = np.concatenate([np.arange(n, dtype=np.int32), [-1]])
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    sidx = shard_index(mesh, ("data",), xp, ip, nbp, stp, gid, dtype="int8")
    want_scale, want_zero = quantization_params(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(sidx.store.plane.scale),
                                  np.asarray(want_scale))
    np.testing.assert_array_equal(np.asarray(sidx.store.plane.zero),
                                  np.asarray(want_zero))
    # pq codebooks follow the same rule: trained over real rows only,
    # replicated across shards like the int8 qparams
    sidx_pq = shard_index(mesh, ("data",), xp, ip, nbp, stp, gid, dtype="pq")
    assert sidx_pq.store.plane.tag == "pq"
    want_cb = train_pq_codebooks(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(sidx_pq.store.plane.codebooks),
                                  np.asarray(want_cb))


# ----------------------------------------------------------------- serving
def test_engine_holds_store_by_reference(plane_index, plane_queries):
    """attach_index + retrieve share the attached store's device buffers —
    one store, zero duplicate device copies (ISSUE-5 satellite)."""
    from repro.serve.engine import ServeEngine

    engine = ServeEngine.__new__(ServeEngine)  # no LM tower needed here
    engine.index = None
    engine.search_backend = "xla"
    engine.search_width = 4
    engine.attach_index(plane_index)
    assert engine.index is plane_index
    assert engine.index.store is plane_index.store
    qv, qi = plane_queries
    res = engine.retrieve(None, qi, sem=Semantics.IF, ef=48, k=10, q_v=qv)
    assert res.ids.shape == (qv.shape[0], 10)
    # retrieve did not re-materialize or swap any store buffer
    assert engine.index.store is plane_index.store
    assert engine.index.store.plane.data is plane_index.store.plane.data
    ptr = lambda a: a.unsafe_buffer_pointer()
    assert ptr(engine.index.store.plane.data) == ptr(plane_index.store.plane.data)
    assert ptr(engine.index.store.nbrs) == ptr(plane_index.store.nbrs)


# ---------------------------------------------------------------- updates
def test_insert_into_quantized_store(plane_index):
    """Streaming inserts encode rows under the frozen quantization params;
    the allocator lives on the store (grow keeps scale/zero buffers)."""
    idx8 = plane_index.with_dtype("int8", rerank=True)
    scale0, zero0 = idx8.store.plane.scale, idx8.store.plane.zero
    new_x = jnp.full((3, idx8.store.dim), 0.33, jnp.float32)
    new_iv = jnp.asarray([[0.2, 0.8]] * 3)
    idx2 = idx8.insert(new_x, new_iv)
    assert idx2.n == idx8.n + 3
    assert idx2.store.plane.tag == "int8"
    np.testing.assert_array_equal(np.asarray(idx2.store.plane.scale),
                                  np.asarray(scale0))
    np.testing.assert_array_equal(np.asarray(idx2.store.plane.zero),
                                  np.asarray(zero0))
    # inserted rows are findable, and the rerank plane keeps them exact
    hit = idx2.search(new_x[:1], jnp.asarray([[0.0, 1.0]]),
                      sem=Semantics.IF, ef=48, k=1)
    slot = int(hit.ids[0, 0])
    assert slot >= 0
    np.testing.assert_allclose(
        np.asarray(idx2.store.rerank.data[slot]), 0.33, atol=1e-6)
    # delete + compact keep the plane consistent
    idx3 = idx2.delete(jnp.asarray([slot])).compact()
    assert idx3.store.plane.data.shape[0] == idx3.n
    assert idx3.store.rerank.data.shape[0] == idx3.n


def test_quantization_params_shapes(plane_index):
    scale, zero = quantization_params(plane_index.x)
    assert scale.shape == (plane_index.store.dim,)
    assert zero.shape == (plane_index.store.dim,)
    assert bool(jnp.all(scale > 0))


# ----------------------------------------------------------------- pq plane
def test_default_pq_m_divides_dim():
    for d in (8, 12, 16, 24, 32, 48, 7, 11):
        m = default_pq_m(d)
        assert m >= 1 and d % m == 0, (d, m)
    assert default_pq_m(24) == 3
    assert default_pq_m(16) == 2


def test_pq_codebook_training_deterministic():
    """Codebook training is a pure function of (data, m, seed): two encodes
    of the same corpus agree bitwise, and frozen-codebook row encoding
    matches full-plane encoding bitwise (the streaming-insert contract)."""
    x = jax.random.normal(jax.random.key(21), (300, 24))
    a = VectorPlane.encode(x, "pq")
    b = VectorPlane.encode(x, "pq")
    m = default_pq_m(24)
    assert a.codebooks.shape == (m, PQ_K, 24 // m)
    assert a.data.shape == (300, m) and a.data.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(a.codebooks),
                                  np.asarray(b.codebooks))
    np.testing.assert_array_equal(np.asarray(a.data), np.asarray(b.data))
    rows = a.encode_rows(x[:9])
    np.testing.assert_array_equal(np.asarray(rows), np.asarray(a.data[:9]))
    # encoding under pre-trained codebooks (the sharded path) is the same
    cb = train_pq_codebooks(x)
    c = VectorPlane.encode(x, "pq", qparams=cb)
    np.testing.assert_array_equal(np.asarray(c.data), np.asarray(a.data))


def test_pq_decode_roundtrip_reasonable():
    x = jax.random.normal(jax.random.key(22), (400, 24))
    plane = VectorPlane.encode(x, "pq")
    assert plane.dim == 24
    dec = plane.decode()
    assert dec.shape == x.shape and dec.dtype == jnp.float32
    rel = float(jnp.linalg.norm(dec - x) / jnp.linalg.norm(x))
    assert rel < 0.5, rel    # coarse codes, but far from garbage
    np.testing.assert_array_equal(np.asarray(plane.decode_rows(jnp.arange(5))),
                                  np.asarray(dec[:5]))


def test_expand_score_pq_backends_bitwise():
    """The Pallas LUT kernel and its chunked XLA twin agree bitwise, across
    chunk widths and batch composition, and honor the shared LUT path."""
    k1, k2, k3 = jax.random.split(jax.random.key(9), 3)
    n, d, B, C = 257, 24, 6, 23
    x = jax.random.normal(k1, (n, d))
    plane = VectorPlane.encode(x, "pq")
    q = jax.random.normal(k2, (B, d))
    idx = jax.random.randint(k3, (B, C), -2, n)
    outs = {
        b: np.asarray(ops.expand_score_plane(plane, idx, q, backend=b))
        for b in ("pallas", "xla")
    }
    np.testing.assert_array_equal(outs["pallas"], outs["xla"])
    assert np.isinf(outs["xla"][np.asarray(idx) < 0]).all()
    from repro.kernels.expand_score import expand_score_pq_xla

    # chunk invariance of the xla twin (elementwise LUT-gather contract)
    for chunk in (1, 3, 7, 19, 32):
        np.testing.assert_array_equal(
            np.asarray(expand_score_pq_xla(
                plane.data, plane.codebooks, idx, q, chunk=chunk)),
            outs["xla"])
    # batch composition: each row scored alone matches its slice of the batch
    for b in ("pallas", "xla"):
        for i in range(B):
            np.testing.assert_array_equal(
                np.asarray(ops.expand_score_plane(
                    plane, idx[i:i + 1], q[i:i + 1], backend=b))[0],
                outs[b][i])
    # precomputed-LUT path (what the fused step uses) is the same program
    lut = ops.pq_lut(plane, q)
    assert lut.shape == (B, plane.codebooks.shape[0], PQ_K)
    for b in ("pallas", "xla"):
        np.testing.assert_array_equal(
            np.asarray(ops.expand_score_plane(plane, idx, q, backend=b,
                                              lut=lut)),
            outs[b])
    # legacy decode-then-score agrees numerically (different f32 association
    # order between the m-fold ADC sum and the d-fold decoded sum: allclose)
    legacy = np.asarray(ops.expand_score_plane(plane, idx, q, backend="legacy"))
    fin = np.isfinite(outs["xla"])
    np.testing.assert_allclose(legacy[fin], outs["xla"][fin], rtol=1e-4,
                               atol=1e-3)


def test_search_step_profile_pq():
    """The pq step keeps the traced-memory contract: no (B, C, d) gather,
    no (·, C, C) dedup tensor, and — the ADC guarantee — no decoded f32
    (n, d) corpus anywhere in the jaxpr."""
    from repro.core.search import search_step_memory_profile

    for backend in ("xla", "pallas"):
        prof = search_step_memory_profile(backend, dtype="pq")
        assert not prof["gather_bcd"], backend
        assert not prof["quadratic_cc"], backend
        assert not prof["decoded_nd"], backend
    legacy = search_step_memory_profile("legacy", dtype="pq")
    assert legacy["gather_bcd"] and legacy["quadratic_cc"]
    assert legacy["decoded_nd"]


def test_pq_rerank_recall_parity(plane_index, plane_queries):
    """pq + f32 rerank stays within 0.05 of the f32 plane on the same graph
    (the ISSUE-7 acceptance bound)."""
    qv, qi = plane_queries
    idxpq = plane_index.with_dtype("pq")
    assert idxpq.dtype == "pq" and idxpq.store.rerank is not None
    for sem in (Semantics.IF, Semantics.IS):
        gt = plane_index.ground_truth(qv, qi, sem=sem, k=10)
        r_f32 = recall(plane_index.search(qv, qi, sem=sem, ef=64, k=10), gt)
        r_pq = recall(idxpq.search(qv, qi, sem=sem, ef=64, k=10), gt)
        assert r_pq >= r_f32 - 0.05, (sem, r_pq, r_f32)


def test_insert_into_pq_store(plane_index):
    """Streaming inserts encode rows under the *frozen* codebooks — the
    same contract as int8 scale/zero — and compact keeps them attached."""
    idxpq = plane_index.with_dtype("pq")
    cb0 = np.asarray(idxpq.store.plane.codebooks)
    new_x = jnp.full((3, idxpq.store.dim), 0.33, jnp.float32)
    new_iv = jnp.asarray([[0.2, 0.8]] * 3)
    idx2 = idxpq.insert(new_x, new_iv)
    assert idx2.n == idxpq.n + 3
    assert idx2.store.plane.tag == "pq"
    np.testing.assert_array_equal(np.asarray(idx2.store.plane.codebooks), cb0)
    # inserted codes match a frozen-codebook re-encode of the same rows
    slot_codes = idx2.store.plane.encode_rows(new_x)
    hit = idx2.search(new_x[:1], jnp.asarray([[0.0, 1.0]]),
                      sem=Semantics.IF, ef=48, k=1)
    slot = int(hit.ids[0, 0])
    assert slot >= 0
    np.testing.assert_array_equal(np.asarray(idx2.store.plane.data[slot]),
                                  np.asarray(slot_codes[0]))
    idx3 = idx2.delete(jnp.asarray([slot])).compact()
    assert idx3.store.plane.data.shape[0] == idx3.n
    np.testing.assert_array_equal(np.asarray(idx3.store.plane.codebooks), cb0)


def test_pq_roundtrips_npz_and_ckpt(plane_index, plane_queries, tmp_path):
    from repro.ckpt import restore_index, save_index

    idxpq = plane_index.with_dtype("pq")
    idxpq.save(tmp_path / "npz")
    back = UGIndex.load(tmp_path / "npz")
    assert back.dtype == "pq"
    _assert_store_bitwise(idxpq.store, back.store)
    qv, qi = plane_queries
    ra = idxpq.search(qv, qi, sem=Semantics.IF, ef=48, k=10)
    rb = back.search(qv, qi, sem=Semantics.IF, ef=48, k=10)
    np.testing.assert_array_equal(np.asarray(ra.ids), np.asarray(rb.ids))
    save_index(tmp_path / "ck", 3, idxpq)
    back2 = restore_index(tmp_path / "ck")
    assert back2.dtype == "pq"
    _assert_store_bitwise(idxpq.store, back2.store)


def test_pq_bytes_per_vector_reduction(plane_index):
    """Codes shrink scan bytes by 4d/m (>= 8x for the default m); the
    amortized figure includes the fixed codebook overhead."""
    x = jax.random.normal(jax.random.key(30), (512, 24))
    plane = VectorPlane.encode(x, "pq")
    m = plane.codebooks.shape[0]
    code_bytes = plane.data.shape[0] * m
    assert (4 * 24 * 512) / code_bytes >= 8.0
    bpv = plane.bytes_per_vector()
    assert bpv == (code_bytes + plane.codebooks.size * 4) / 512


# ------------------------------------------------- accounting regressions
def test_bytes_per_vector_across_grow(plane_index):
    """ISSUE-7 satellite: bytes/vec must amortize over *live* rows, not
    capacity — after grow() doubles the buffers the reported figure rises
    (fixed overhead over the same live set), it must never halve."""
    d = plane_index.store.dim
    before = plane_index.vector_memory_bytes()["plane_bytes_per_vector"]
    assert before == 4 * d
    new_x = jnp.full((3, d), 0.25, jnp.float32)
    new_iv = jnp.asarray([[0.1, 0.9]] * 3)
    idx2 = plane_index.insert(new_x, new_iv)     # static index: forces grow
    assert idx2.capacity > plane_index.capacity
    after = idx2.vector_memory_bytes()["plane_bytes_per_vector"]
    assert after >= 4 * d                        # never below the row cost
    want = 4 * d * idx2.capacity / idx2.n
    assert abs(after - want) < 1e-6, (after, want)
    # capacity-denominated (the old bug) would report exactly 4*d here
    assert after > 4 * d * 1.5


def test_masks_memory_bytes_accounting(plane_index):
    """ISSUE-7 satellite: masks bytes charge 1 byte/slot per *present*
    mask — alive-only stores must not be billed for a free mask."""
    st = plane_index.store
    cap = st.capacity
    assert st.memory_bytes()["masks"] == 0            # static: no masks
    alive = jnp.ones((cap,), bool)
    assert st.replace(alive=alive).memory_bytes()["masks"] == cap
    both = st.replace(alive=alive, free=jnp.zeros((cap,), bool))
    assert both.memory_bytes()["masks"] == 2 * cap
    assert st.live_count() == cap
    assert both.replace(alive=alive.at[0].set(False)).live_count() == cap - 1
