"""Fused prune-sweep kernel: backends vs a numpy Alg. 3 oracle.

Construction correctness contract (ISSUE 2): the ``pallas`` / ``xla`` /
``legacy`` sweeps must return *bit-identical* ``status`` / ``repair_if`` /
``repair_is`` across a grid of shapes, alphas, semantics modes, degenerate
(point) intervals and all-pad rows — and the fused backends must never
materialize a ``(B, C, C)`` witness/distance tensor.  Mirrors the
test_beam_merge.py oracle style, one level down the build stack.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import intervals as iv
from repro.core.build import UGConfig, build_ug
from repro.core.prune import unified_prune
from repro.kernels import ops
from repro.kernels import prune_sweep as ps

pytestmark = pytest.mark.hermetic  # runs in the no-hypothesis CI job

BACKENDS = ("legacy", "xla", "pallas")
# Exactly f32-representable alphas so α² is bit-identical in every backend
# and in the float64 oracle.
ALPHAS = (1.0, 1.25)


def make_case(seed, B, C, d, *, point=False, pad_frac=0.2, grid=False):
    """Synthetic *preprocessed* sweep inputs (the ops.prune_sweep contract).

    ``grid=True`` draws vectors/intervals from tiny exact-float grids so
    every distance and comparison is exact — the deliberate-ties regime.
    """
    rng = np.random.default_rng(seed)
    if grid:
        xs = rng.choice([0.0, 0.5, 1.0, 2.0], size=(B, C, d)).astype(np.float32)
        ends = rng.choice([0.0, 0.25, 0.5, 0.75, 1.0], size=(B, C, 2))
    else:
        xs = rng.normal(size=(B, C, d)).astype(np.float32)
        ends = rng.uniform(size=(B, C, 2))
    i_c = np.sort(ends, axis=-1).astype(np.float32)
    if point:
        i_c[..., 1] = i_c[..., 0]            # degenerate (RF-style) intervals
    i_u = np.sort(rng.uniform(size=(B, 2)), axis=-1).astype(np.float32)

    d_uc = rng.uniform(0.1, 4.0, size=(B, C)).astype(np.float32)
    valid = rng.uniform(size=(B, C)) >= pad_frac
    d_uc[~valid] = np.inf
    inter_l = np.maximum(i_u[:, None, 0], i_c[..., 0])
    inter_r = np.minimum(i_u[:, None, 1], i_c[..., 1])
    overlap = inter_l <= inter_r
    return tuple(map(jnp.asarray, (i_u, xs, i_c, d_uc, valid, overlap)))


def np_oracle(i_u, xs, i_c, d_uc, valid, overlap, *, m_if, m_is, alpha, unified):
    """Direct float64 transcription of Alg. 3 (scan with witness rows)."""
    i_u, xs, i_c, d_uc, valid, overlap = map(np.asarray, (i_u, xs, i_c, d_uc, valid, overlap))
    B, C = d_uc.shape
    a2 = np.float64(np.float32(alpha)) ** 2
    status = np.zeros((B, C), np.int32)
    rif = np.full((B, C), -1, np.int32)
    ris = np.full((B, C), -1, np.int32)
    for b in range(B):
        act_if = np.zeros(C, bool)
        act_is = np.zeros(C, bool)
        cnt_if = cnt_is = 0
        xb = xs[b].astype(np.float64)
        for t in range(C):
            d_row = ((xb - xb[t]) ** 2).sum(-1)
            geo = (np.arange(C) < t) & (a2 * d_row < np.float64(d_uc[b, t]))
            if unified:
                hl = min(i_u[b, 0], i_c[b, t, 0]); hr = max(i_u[b, 1], i_c[b, t, 1])
                phi_if = (hl <= i_c[b, :, 0]) & (i_c[b, :, 1] <= hr)
                il = max(i_u[b, 0], i_c[b, t, 0]); ir = min(i_u[b, 1], i_c[b, t, 1])
                phi_is = (il <= ir) & (i_c[b, :, 0] <= il) & (i_c[b, :, 1] >= ir)
            else:
                phi_if = phi_is = np.ones(C, bool)
            wit_if = geo & act_if & phi_if
            wit_is = geo & act_is & phi_is
            s_if = valid[b, t]
            s_is = valid[b, t] and bool(overlap[b, t])
            keep_if = s_if and not wit_if.any() and cnt_if < m_if
            keep_is = s_is and not wit_is.any() and cnt_is < m_is
            cnt_if += keep_if
            cnt_is += keep_is
            act_if[t] = keep_if
            act_is[t] = keep_is
            status[b, t] = keep_if * iv.FLAG_IF + keep_is * iv.FLAG_IS
            if s_if and wit_if.any():
                rif[b, t] = int(np.argmax(wit_if))
            if s_is and wit_is.any():
                ris[b, t] = int(np.argmax(wit_is))
    return status, rif, ris


def _run(backend, case, **kw):
    st, rif, ris = ops.prune_sweep(*case, backend=backend, **kw)
    return np.asarray(st), np.asarray(rif), np.asarray(ris)


@pytest.mark.parametrize("B,C,d", [(1, 8, 4), (5, 33, 16), (16, 96, 24), (3, 5, 2)])
@pytest.mark.parametrize("alpha", ALPHAS)
@pytest.mark.parametrize("unified", [True, False])
def test_backends_bitwise_identical(B, C, d, alpha, unified):
    case = make_case(B * 1000 + C + d, B, C, d)
    outs = {b: _run(b, case, m_if=8, m_is=8, alpha=alpha, unified=unified)
            for b in BACKENDS}
    for b in ("xla", "pallas"):
        for ref, got in zip(outs["legacy"], outs[b]):
            assert np.array_equal(ref, got), (b, B, C, alpha, unified)


@pytest.mark.parametrize("grid", [False, True])
@pytest.mark.parametrize("point", [False, True])
def test_matches_numpy_oracle(grid, point):
    """Backends == the literal Alg. 3 transcription, including the exact-tie
    grid regime and degenerate (point) object intervals."""
    case = make_case(7 + grid + 2 * point, 6, 24, 8, point=point, grid=grid)
    kw = dict(m_if=5, m_is=5, alpha=1.0, unified=True)
    want = np_oracle(*case, **kw)
    for b in BACKENDS:
        got = _run(b, case, **kw)
        for w, g in zip(want, got):
            assert np.array_equal(w, g), b


def test_degree_budget_respected():
    case = make_case(11, 4, 40, 8, pad_frac=0.0)
    for m in (1, 3, 7):
        st, _, _ = _run("xla", case, m_if=m, m_is=m, alpha=1.0, unified=True)
        assert ((st & iv.FLAG_IF) > 0).sum(axis=1).max() <= m
        assert ((st & iv.FLAG_IS) > 0).sum(axis=1).max() <= m


def test_all_pad_rows_inert():
    """Rows whose candidates are all padding stay fully pruned with no
    repair offers, on every backend."""
    i_u, xs, i_c, d_uc, valid, overlap = make_case(13, 5, 16, 8)
    valid = valid.at[2].set(False)
    d_uc = d_uc.at[2].set(jnp.inf)
    case = (i_u, xs, i_c, d_uc, valid, overlap)
    for b in BACKENDS:
        st, rif, ris = _run(b, case, m_if=4, m_is=4, alpha=1.0, unified=True)
        assert (st[2] == 0).all(), b
        assert (rif[2] == -1).all() and (ris[2] == -1).all(), b


def test_pallas_block_size_invariant():
    """The elementwise distance rows make the sweep bitwise independent of
    the bb row tiling (DESIGN.md §9) — unlike a matmul-identity kernel."""
    case = make_case(17, 13, 48, 8)
    kw = dict(m_if=6, m_is=6, alpha=1.25, unified=True)
    ref = _run("pallas", case, bb=32, **kw)
    for bb in (1, 4, 8, 64):
        got = _run("pallas", case, bb=bb, **kw)
        for r, g in zip(ref, got):
            assert np.array_equal(r, g), bb


def test_fused_never_materializes_quadratic():
    """ISSUE-2 acceptance: no (·, C, C) Φ/distance tensor in the fused
    sweeps; the legacy trace keeps them (that is what fusion removes)."""
    for backend in ("xla", "pallas"):
        prof = ps.sweep_memory_profile(backend, B=32, C=64, d=16)
        assert not prof["quadratic"], backend
    legacy = ps.sweep_memory_profile("legacy", B=32, C=64, d=16)
    assert legacy["quadratic"]
    assert legacy["peak_bytes"] > ps.sweep_memory_profile("xla", B=32, C=64, d=16)["peak_bytes"]


def test_unknown_backend_rejected():
    case = make_case(19, 2, 8, 4)
    with pytest.raises(ValueError):
        ops.prune_sweep(*case, m_if=4, m_is=4, backend="mosaic")


# ------------------------------------------------------- end-to-end parity
def test_unified_prune_backend_parity(small_corpus):
    """Full unified_prune (dedup + sort + sweep + repair remap) is
    bit-identical across backends on a real corpus with duplicate, self and
    padded candidate ids."""
    x, ints = small_corpus
    n = x.shape[0]
    rng = np.random.default_rng(0)
    B, C = 24, 40
    cand = rng.integers(-4, n, size=(B, C)).astype(np.int32)
    cand[:, 5] = cand[:, 3]               # forced duplicates
    cand[:, 7] = np.arange(B)             # forced self edges
    u = jnp.arange(B, dtype=jnp.int32)
    cand = jnp.asarray(cand)
    outs = {
        b: unified_prune(u, cand, x, ints, m_if=8, m_is=8, alpha=1.0,
                         unified=True, backend=b)
        for b in BACKENDS
    }
    for b in ("xla", "pallas"):
        for f in outs[b]._fields:
            assert np.array_equal(
                np.asarray(getattr(outs[b], f)), np.asarray(getattr(outs["legacy"], f))
            ), (b, f)


def test_build_determinism_across_backends(small_corpus):
    """Same key/config ⇒ byte-identical DenseGraph on every backend (the
    jitted lax.map sweep included)."""
    x, ints = small_corpus
    cfg = dict(ef_spatial=16, ef_attribute=32, max_edges_if=16, max_edges_is=16,
               iterations=2, repair_width=8, exact_spatial=True, block=96)
    graphs = {
        b: build_ug(jax.random.key(3), x, ints, UGConfig(prune_backend=b, **cfg))
        for b in BACKENDS
    }
    ref = graphs["legacy"]
    for b in ("xla", "pallas"):
        assert np.array_equal(np.asarray(graphs[b].nbrs), np.asarray(ref.nbrs)), b
        assert np.array_equal(np.asarray(graphs[b].status), np.asarray(ref.status)), b
    # and rebuilding with the same backend reproduces the same bytes
    again = build_ug(jax.random.key(3), x, ints, UGConfig(prune_backend="xla", **cfg))
    assert np.array_equal(np.asarray(again.nbrs), np.asarray(ref.nbrs))
