"""Edge cases for the visited bitmap and the NULL-entry search path.

The bitmap replaces Alg. 4's visited hash-set with one uint32 word per 32
nodes; its soundness relies on `_bitmap_set`'s scatter-*add* acting as an OR,
which only holds when no bit is added twice.  These tests pin the boundary
conditions: n not a multiple of 32, duplicate ids offered across steps, and
the ``start = -1`` certified-NULL path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import intervals as iv
from repro.core.exact import build_exact
from repro.core.entry import build_entry_index
from repro.core.search import _bitmap_set, _bitmap_test, beam_search, brute_force
from repro.core.store import make_store


def test_bitmap_n_not_multiple_of_32():
    n = 37                      # 2 words, 27 slack bits in the last word
    nwords = (n + 31) // 32
    bm = jnp.zeros((nwords,), jnp.uint32)
    ids = jnp.asarray([0, 31, 32, 36], jnp.int32)
    bm = _bitmap_set(bm, ids, jnp.ones((4,), bool))
    assert bool(_bitmap_test(bm, ids).all())
    others = jnp.asarray([1, 30, 33, 35], jnp.int32)
    assert not bool(_bitmap_test(bm, others).any())


def test_bitmap_duplicate_ids_across_steps():
    """Re-offering an already-set id with fresh=~test is an exact no-op, so
    add == or across any number of steps."""
    n = 70
    bm = jnp.zeros(((n + 31) // 32,), jnp.uint32)
    step1 = jnp.asarray([3, 64, 69], jnp.int32)
    bm = _bitmap_set(bm, step1, ~_bitmap_test(bm, step1))
    before = np.asarray(bm).copy()
    # step 2 offers duplicates of step 1 plus one new id
    step2 = jnp.asarray([3, 69, 5], jnp.int32)
    bm = _bitmap_set(bm, step2, ~_bitmap_test(bm, step2))
    after = np.asarray(bm)
    assert bool(_bitmap_test(bm, jnp.asarray([3, 64, 69, 5])).all())
    # words holding only old ids unchanged (no double-add corruption)
    assert after[2] == before[2]  # word of 64/69 also got 69 re-offered: equal
    popcount = sum(bin(int(w)).count("1") for w in after)
    assert popcount == 4


def test_bitmap_set_respects_fresh_mask():
    bm = jnp.zeros((2,), jnp.uint32)
    ids = jnp.asarray([4, 4], jnp.int32)      # duplicate in one batch,
    fresh = jnp.asarray([True, False])        # but only one marked fresh
    bm = _bitmap_set(bm, ids, fresh)
    assert int(np.asarray(bm)[0]) == 1 << 4


@pytest.mark.parametrize("backend", ["legacy", "xla", "pallas"])
def test_no_valid_entry_returns_all_invalid(backend, small_corpus):
    """start = -1 (certified NULL): every slot -1 / +inf, zero steps."""
    x, ints = small_corpus
    g = build_exact(x, ints, unified=True)
    qv = jnp.zeros((3, x.shape[1]))
    entry = jnp.full((3,), -1, jnp.int32)
    qi = jnp.asarray([[-5.0, 5.0]] * 3, jnp.float32)  # IS-impossible window
    store = make_store(x, ints, g.nbrs, g.status)
    res = beam_search(store, entry, qv, qi,
                      sem=iv.Semantics.IS, ef=16, k=5, backend=backend)
    assert bool((res.ids == -1).all())
    assert bool(jnp.isinf(res.dist).all())
    assert bool((res.steps == 0).all())


def test_duplicate_neighbors_within_fused_step(small_corpus):
    """The exact URNG has heavily overlapping neighbor lists; expanding W=8
    nodes at once must still dedup scoring (full recall, no repeated ids)."""
    x, ints = small_corpus
    g = build_exact(x, ints, unified=True)
    eidx = build_entry_index(ints)
    from repro.core.search import search
    k1, k2 = jax.random.split(jax.random.key(17))
    qv = jax.random.normal(k1, (16, x.shape[1]))
    c = jax.random.uniform(k2, (16, 1))
    qi = jnp.concatenate([jnp.maximum(c - 0.3, 0), jnp.minimum(c + 0.3, 1)], axis=1)
    store = make_store(x, ints, g.nbrs, g.status, entry=eidx)
    res = search(store, qv, qi,
                 sem=iv.Semantics.IF, ef=32, k=10, backend="xla", width=8)
    gt = brute_force(x, ints, qv, qi, sem=iv.Semantics.IF, k=10)
    from repro.core.index import recall
    assert recall(res, gt) == 1.0
    ids = np.asarray(res.ids)
    for row in ids:
        real = [v for v in row if v >= 0]
        assert len(real) == len(set(real)), row
