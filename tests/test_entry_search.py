"""Entry acquisition (Alg. 5 / Lemma 4.3) + beam search (Alg. 4) tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import intervals as iv
from repro.core.build import UGConfig
from repro.core.entry import build_entry_index, get_entry, get_entry_batch
from repro.core.exact import build_exact
from repro.core.index import UGIndex, recall
from repro.core.search import brute_force, search
from repro.core.store import make_store

pytestmark = pytest.mark.hermetic  # runs in the no-hypothesis CI job

unit = st.floats(0, 1, allow_nan=False, width=32)


@pytest.fixture(scope="module")
def eidx_data():
    k = jax.random.key(3)
    ints = iv.sample_uniform_intervals(k, 500)
    return ints, build_entry_index(ints)


@settings(max_examples=60, deadline=None)
@given(unit, unit)
def test_entry_lemma_4_3(ql, qr):
    """Returned node satisfies the predicate; NULL implies none exists."""
    k = jax.random.key(3)
    ints = iv.sample_uniform_intervals(k, 500)
    eidx = build_entry_index(ints)
    lo, hi = min(ql, qr), max(ql, qr)
    q = jnp.asarray([lo, hi], jnp.float32)
    ints_np = np.asarray(ints)
    for sem in (iv.Semantics.IF, iv.Semantics.IS):
        e = int(get_entry(eidx, q, sem))
        if sem is iv.Semantics.IF:
            any_valid = bool(((ints_np[:, 0] >= lo) & (ints_np[:, 1] <= hi)).any())
            if e >= 0:
                assert ints_np[e, 0] >= lo and ints_np[e, 1] <= hi
            else:
                assert not any_valid
        else:
            any_valid = bool(((ints_np[:, 0] <= lo) & (ints_np[:, 1] >= hi)).any())
            if e >= 0:
                assert ints_np[e, 0] <= lo and ints_np[e, 1] >= hi
            else:
                assert not any_valid


@settings(max_examples=40, deadline=None)
@given(unit, unit)
def test_entry_batch_widened_lemma(ql, qr):
    """Widened Alg. 5: every non-NULL id in the batch is a valid entry,
    ids are distinct, and column 0 equals the single-entry result."""
    k = jax.random.key(3)
    ints = iv.sample_uniform_intervals(k, 500)
    eidx = build_entry_index(ints)
    lo, hi = min(ql, qr), max(ql, qr)
    q = jnp.asarray([lo, hi], jnp.float32)
    ints_np = np.asarray(ints)
    for sem in (iv.Semantics.IF, iv.Semantics.IS):
        batch = np.asarray(get_entry_batch(eidx, q, sem, width=6))
        assert batch.shape == (6,)
        assert int(batch[0]) == int(get_entry(eidx, q, sem))
        real = [int(v) for v in batch if v >= 0]
        assert len(real) == len(set(real))
        for e in real:
            if sem is iv.Semantics.IF:
                assert ints_np[e, 0] >= lo and ints_np[e, 1] <= hi
            else:
                assert ints_np[e, 0] <= lo and ints_np[e, 1] >= hi


def test_entry_batch_batched_queries(eidx_data):
    """Batch axis broadcasting: (B, 2) query intervals -> (B, W) ids."""
    ints, eidx = eidx_data
    q = jnp.asarray([[0.0, 1.0], [0.4, 0.6], [2.0, 3.0]], jnp.float32)
    out = get_entry_batch(eidx, q, iv.Semantics.IF, width=4)
    assert out.shape == (3, 4)
    assert int(out[0, 0]) >= 0         # whole domain: entry must exist
    assert bool((out[2] == -1).all())  # out-of-range window: certified NULL


def test_entry_masked(eidx_data):
    """node_mask excludes rows from entry consideration (sharded pad rows)."""
    ints, _ = eidx_data
    mask = jnp.arange(ints.shape[0]) < 100
    eidx = build_entry_index(ints, node_mask=mask)
    q = jnp.asarray([0.0, 1.0], jnp.float32)
    e = int(get_entry(eidx, q, iv.Semantics.IF))
    assert 0 <= e < 100


def test_search_exact_graph_full_recall(small_corpus, queries):
    """On the exact URNG, beam search recall@10 == 1.0 (Cor. 3.4 + heredity)."""
    x, ints = small_corpus
    g = build_exact(x, ints, unified=True)
    eidx = build_entry_index(ints)
    qv, qi = queries
    store = make_store(x, ints, g.nbrs, g.status, entry=eidx)
    for sem in (iv.Semantics.IF, iv.Semantics.IS):
        res = search(store, qv, qi, sem=sem, ef=48, k=10)
        gt = brute_force(x, ints, qv, qi, sem=sem, k=10)
        assert recall(res, gt) == 1.0, sem


def test_search_no_valid_nodes(small_corpus):
    """Impossible queries return all -1 (NULL entry path)."""
    x, ints = small_corpus
    g = build_exact(x, ints, unified=True)
    eidx = build_entry_index(ints)
    qv = jnp.zeros((2, x.shape[1]))
    impossible = jnp.asarray([[0.4999, 0.5001], [0.5, 0.5]], jnp.float32)
    store = make_store(x, ints, g.nbrs, g.status, entry=eidx)
    res = search(store, qv, impossible,
                 sem=iv.Semantics.IS, ef=16, k=5)
    # IS with a near-point query can have matches; use an out-of-range one
    impossible2 = jnp.asarray([[-5.0, 5.0], [-5.0, 5.0]], jnp.float32)
    res2 = search(store, qv, impossible2,
                  sem=iv.Semantics.IS, ef=16, k=5)
    assert bool((res2.ids == -1).all())


@pytest.mark.slow
def test_search_results_satisfy_predicate(medium_corpus):
    """Every returned id satisfies the query predicate (search never leaves
    the valid subgraph — Alg. 4 lines 11-20)."""
    x, ints = medium_corpus
    cfg = UGConfig(ef_spatial=24, ef_attribute=48, max_edges_if=24, max_edges_is=24,
                   iterations=2, repair_width=8, exact_spatial=True, block=768)
    idx = UGIndex.build(x, ints, cfg)
    k1, k2 = jax.random.split(jax.random.key(9))
    qv = jax.random.normal(k1, (24, x.shape[1]))
    c = jax.random.uniform(k2, (24, 1))
    qi = jnp.concatenate([jnp.maximum(c - 0.3, 0), jnp.minimum(c + 0.3, 1)], axis=1)
    ints_np = np.asarray(ints)
    for sem in (iv.Semantics.IF, iv.Semantics.IS):
        res = idx.search(qv, qi, sem=sem, ef=48, k=10)
        ids = np.asarray(res.ids)
        qn = np.asarray(qi)
        for i in range(ids.shape[0]):
            for v in ids[i]:
                if v < 0:
                    continue
                if sem is iv.Semantics.IF:
                    assert qn[i, 0] <= ints_np[v, 0] and ints_np[v, 1] <= qn[i, 1]
                else:
                    assert ints_np[v, 0] <= qn[i, 0] and qn[i, 1] <= ints_np[v, 1]


@pytest.mark.slow
def test_ug_recall_threshold(medium_corpus):
    """Practical UG achieves high recall on all four semantics (Exp-1/2)."""
    x, ints = medium_corpus
    cfg = UGConfig(ef_spatial=32, ef_attribute=64, max_edges_if=32, max_edges_is=32,
                   iterations=3, repair_width=16, exact_spatial=True, block=768)
    idx = UGIndex.build(x, ints, cfg)
    k1, k2 = jax.random.split(jax.random.key(11))
    nq = 32
    qv = jax.random.normal(k1, (nq, x.shape[1]))
    c = jax.random.uniform(k2, (nq, 1))
    qi = jnp.concatenate([jnp.maximum(c - 0.3, 0), jnp.minimum(c + 0.3, 1)], axis=1)
    point = jnp.concatenate([c, c], axis=1)
    for sem, q in [
        (iv.Semantics.IF, qi), (iv.Semantics.IS, qi), (iv.Semantics.RS, point),
    ]:
        res = idx.search(qv, q, sem=sem, ef=96, k=10)
        gt = idx.ground_truth(qv, q, sem=sem, k=10)
        r = recall(res, gt)
        assert r >= 0.85, f"{sem}: recall {r}"


def test_degree_budgets(medium_corpus):
    """Per-semantic out-degree never exceeds max_edges (Alg. 3 lines 18-21)."""
    x, ints = medium_corpus
    cfg = UGConfig(ef_spatial=24, ef_attribute=48, max_edges_if=12, max_edges_is=9,
                   iterations=2, repair_width=8, exact_spatial=True, block=768)
    idx = UGIndex.build(x, ints, cfg)
    assert int(idx.graph.degree(iv.FLAG_IF).max()) <= 12
    assert int(idx.graph.degree(iv.FLAG_IS).max()) <= 9


def test_save_load_roundtrip(tmp_path, medium_corpus):
    x, ints = medium_corpus
    cfg = UGConfig(ef_spatial=16, ef_attribute=32, max_edges_if=16, max_edges_is=16,
                   iterations=1, exact_spatial=True, block=768)
    idx = UGIndex.build(x, ints, cfg)
    idx.save(tmp_path / "idx")
    idx2 = UGIndex.load(tmp_path / "idx")
    assert bool(jnp.array_equal(idx.graph.nbrs, idx2.graph.nbrs))
    assert bool(jnp.array_equal(idx.graph.status, idx2.graph.status))
    assert idx2.config.max_edges_if == 16
