"""Mixed-batch parity suite: runtime semantics (DESIGN.md §10).

The headline contract of the runtime-semantics path: a shuffled IF/IS/RF/RS
batch through one compiled program returns **bitwise-identical** ids, dists
and step counts to four per-semantics ``beam_search`` calls — across both
fused backends, both frontier widths, and the legacy loop.  Also here: the
flag-driven entry acquisition parity, NULL-row behavior inside a mixed
batch, and the shape-bucketed ``ServeEngine.retrieve_mixed`` serving path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Semantics, UGConfig, UGIndex, as_sem_flags, recall
from repro.core import intervals as iv
from repro.core.entry import (
    build_entry_index,
    get_entry,
    get_entry_batch,
    get_entry_batch_flags,
    get_entry_flags,
)

CYCLE = [Semantics.IF, Semantics.IS, Semantics.RS, Semantics.RF]


@pytest.fixture(scope="module")
def mixed_index():
    """Small UG kept cheap enough for pallas interpret mode (M stays small)."""
    k1, k2 = jax.random.split(jax.random.key(7))
    n, d = 400, 12
    x = jax.random.normal(k1, (n, d))
    ints = iv.sample_uniform_intervals(k2, n)
    cfg = UGConfig(ef_spatial=16, ef_attribute=32, max_edges_if=12,
                   max_edges_is=12, iterations=2, repair_width=8,
                   exact_spatial=True, block=512)
    return UGIndex.build(x, ints, cfg)


@pytest.fixture(scope="module")
def mixed_queries(mixed_index):
    """16 queries, semantics cycling IF/IS/RS/RF then shuffled."""
    nq = 16
    k1, k2 = jax.random.split(jax.random.key(17))
    qv = jax.random.normal(k1, (nq, mixed_index.x.shape[1]))
    c = jax.random.uniform(k2, (nq, 1))
    wide = jnp.concatenate([jnp.maximum(c - 0.3, 0), jnp.minimum(c + 0.3, 1)], axis=1)
    point = jnp.concatenate([c, c], axis=1)
    order = np.random.default_rng(3).permutation(nq)
    sems = [CYCLE[i % 4] for i in order]
    qm = jnp.where(jnp.asarray([s is Semantics.RS for s in sems])[:, None],
                   point, wide)
    return qv, qm, sems


def _subsets(sems):
    return {s: np.asarray([i for i, ss in enumerate(sems) if ss is s])
            for s in CYCLE}


@pytest.mark.parametrize("backend,width", [
    ("xla", 1), ("xla", 4), ("pallas", 1), ("pallas", 4),
])
def test_mixed_matches_per_semantics_bitwise(mixed_index, mixed_queries, backend, width):
    """One mixed program == four per-semantics programs, bit for bit."""
    qv, qm, sems = mixed_queries
    res = mixed_index.search_mixed(qv, qm, sems, ef=32, k=10,
                                   backend=backend, width=width)
    for s, sel in _subsets(sems).items():
        ref = mixed_index.search(qv[sel], qm[sel], sem=s, ef=32, k=10,
                                 backend=backend, width=width)
        assert np.array_equal(np.asarray(res.ids)[sel], np.asarray(ref.ids)), s
        assert np.array_equal(np.asarray(res.dist)[sel], np.asarray(ref.dist)), s
        assert np.array_equal(np.asarray(res.steps)[sel], np.asarray(ref.steps)), s


def test_mixed_matches_per_semantics_legacy(mixed_index, mixed_queries):
    """The legacy vmap loop is flag-driven too (one program, no static sem)."""
    qv, qm, sems = mixed_queries
    res = mixed_index.search_mixed(qv, qm, sems, ef=32, k=10, backend="legacy")
    for s, sel in _subsets(sems).items():
        ref = mixed_index.search(qv[sel], qm[sel], sem=s, ef=32, k=10,
                                 backend="legacy")
        assert np.array_equal(np.asarray(res.ids)[sel], np.asarray(ref.ids)), s
        assert np.array_equal(np.asarray(res.dist)[sel], np.asarray(ref.dist)), s


def test_mixed_recall_against_ground_truth(mixed_index, mixed_queries):
    """The mixed program is still a good ANN index, per semantics.

    Thresholds are calibrated to this deliberately tiny fixture (n=400,
    degree 12, kept small for pallas interpret mode): wide-window IS is
    connectivity-limited here for *every* backend including legacy — the
    production-scale ≥0.9 floor lives in test_recall_regression.py, and the
    bitwise parity tests above transfer it to the mixed path verbatim."""
    qv, qm, sems = mixed_queries
    res = mixed_index.search_mixed(qv, qm, sems, ef=64, k=10)
    floor = {Semantics.IF: 0.9, Semantics.RF: 0.9,
             Semantics.RS: 0.85, Semantics.IS: 0.3}
    for s, sel in _subsets(sems).items():
        gt = mixed_index.ground_truth(qv[sel], qm[sel], sem=s, k=10)
        part = type(res)(res.ids[sel], res.dist[sel], res.steps[sel])
        assert recall(part, gt) >= floor[s], s


def test_mixed_null_rows_stay_null(mixed_index, mixed_queries):
    """Unsatisfiable rows inside a mixed batch return all -1 without
    perturbing their neighbors (no-op rows in the shared while_loop)."""
    qv, qm, sems = mixed_queries
    qdead = qm.at[3].set(jnp.asarray([2.0, -2.0]))  # IF window below any l
    sems = list(sems)
    sems[3] = Semantics.IF
    res = mixed_index.search_mixed(qv, qdead, sems, ef=32, k=10)
    assert bool((res.ids[3] == -1).all())
    # other rows equal the same batch without the dead row's query changed
    keep = [i for i in range(qv.shape[0]) if i != 3]
    ref = mixed_index.search_mixed(qv[np.asarray(keep)], qdead[np.asarray(keep)],
                                   [sems[i] for i in keep], ef=32, k=10)
    assert np.array_equal(np.asarray(res.ids)[keep], np.asarray(ref.ids))


def test_as_sem_flags_forms():
    flags = as_sem_flags(Semantics.IS, 3)
    assert flags.tolist() == [iv.FLAG_IS] * 3
    flags = as_sem_flags([Semantics.IF, Semantics.RS], 2)
    assert flags.tolist() == [iv.FLAG_IF, iv.FLAG_IS]
    flags = as_sem_flags(jnp.asarray([1, 2, 1]), 3)
    assert flags.dtype == jnp.int32
    with pytest.raises(ValueError):
        as_sem_flags([Semantics.IF], 2)
    # flag 0 would silently NULL every row: host-side values are validated
    with pytest.raises(ValueError):
        as_sem_flags([0, 1], 2)
    with pytest.raises(ValueError):
        as_sem_flags(np.asarray([1, 3]), 2)


def test_predicate_by_flag_matches_static():
    k1, k2 = jax.random.split(jax.random.key(5))
    obj = iv.sample_uniform_intervals(k1, 64)
    q = iv.sample_uniform_intervals(k2, 64)
    for sem in (Semantics.IF, Semantics.IS):
        flags = jnp.full((64,), sem.flag, jnp.int32)
        got = iv.predicate_by_flag(flags, obj, q)
        assert np.array_equal(np.asarray(got), np.asarray(iv.predicate(sem, obj, q)))
    mask = iv.query_valid_mask_by_flag(
        jnp.asarray([iv.FLAG_IF, iv.FLAG_IS], jnp.int32), obj, q[:2])
    assert np.array_equal(np.asarray(mask[0]),
                          np.asarray(iv.query_valid_mask(Semantics.IF, obj, q[0])))
    assert np.array_equal(np.asarray(mask[1]),
                          np.asarray(iv.query_valid_mask(Semantics.IS, obj, q[1])))


def test_entry_flags_parity(mixed_index, mixed_queries):
    """Flag-driven Alg. 5 == the static branch, single and widened."""
    qv, qm, sems = mixed_queries
    eidx = mixed_index.entry
    flags = as_sem_flags(sems, qm.shape[0])
    one = np.asarray(get_entry_flags(eidx, qm, flags))
    batch = np.asarray(get_entry_batch_flags(eidx, qm, flags, width=4))
    for s, sel in _subsets(sems).items():
        assert np.array_equal(one[sel], np.asarray(get_entry(eidx, qm[sel], s)))
        assert np.array_equal(
            batch[sel], np.asarray(get_entry_batch(eidx, qm[sel], s, width=4)))


def test_entry_flags_masked_index(mixed_index):
    """Flag path respects node masks (sharded pad-row soundness)."""
    ints = mixed_index.intervals
    mask = jnp.arange(ints.shape[0]) < 100
    eidx = build_entry_index(ints, node_mask=mask)
    # IF: the whole domain; IS: a point query (a wide IS window may have no
    # containing object at all, which would be a correct NULL)
    q = jnp.asarray([[0.0, 1.0], [0.5, 0.5]], jnp.float32)
    flags = jnp.asarray([iv.FLAG_IF, iv.FLAG_IS], jnp.int32)
    ids = np.asarray(get_entry_flags(eidx, q, flags))
    assert (ids >= 0).all() and (ids < 100).all()


def test_serve_engine_retrieve_mixed_bucketing(mixed_index, mixed_queries):
    """The bucketed serving path pads to a bucket shape and returns exactly
    the unpadded mixed-search answers (retrieval is model-independent when
    embeddings are precomputed)."""
    from repro.serve.engine import ServeEngine, bucket_batch_size

    assert bucket_batch_size(1) == 8
    assert bucket_batch_size(8) == 8
    assert bucket_batch_size(9) == 16
    assert bucket_batch_size(5000) == 5120

    qv, qm, sems = mixed_queries
    engine = ServeEngine.__new__(ServeEngine)  # no LM tower needed here
    engine.index = None
    engine.search_backend = "xla"
    engine.search_width = 4
    engine.attach_index(mixed_index)
    B = 13  # forces padding to the 16-bucket
    res = engine.retrieve_mixed(None, qm[:B], sems[:B], ef=32, k=10, q_v=qv[:B])
    assert res.ids.shape == (B, 10)
    ref = mixed_index.search_mixed(qv[:B], qm[:B], sems[:B], ef=32, k=10,
                                   backend="xla", width=4)
    assert np.array_equal(np.asarray(res.ids), np.asarray(ref.ids))
    assert np.array_equal(np.asarray(res.dist), np.asarray(ref.dist))
