"""Per-architecture smoke tests (harness deliverable f).

Every assigned arch instantiates its REDUCED config and runs one forward /
train step on CPU asserting output shapes + finite values, plus one serve
(decode) step.  Full configs are exercised only via the dry-run.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, SHAPES, get_arch, input_specs
from repro.models.api import get_model
from repro.train import AdamWConfig, make_train_step, optim

ALL_ARCHS = sorted(ARCHS)


def _batch(cfg, B=2, S=24):
    ks = jax.random.split(jax.random.key(0), 3)
    b = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(ks[2], (B, S // 2, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_train_step(arch):
    spec = get_arch(arch)
    cfg = spec.reduced
    model = get_model(cfg)
    params = model.init(jax.random.key(1))
    batch = _batch(cfg)

    loss, metrics = model.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"

    ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=4)
    ostate = optim.init(ocfg, params)
    step = make_train_step(model, ocfg, donate=False)
    new_p, new_o, m = step(params, ostate, batch)
    assert bool(jnp.isfinite(m["loss"]))
    # params actually changed
    delta = sum(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).sum())
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_p))
    )
    assert delta > 0, f"{arch}: no parameter update"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_serve_step(arch):
    spec = get_arch(arch)
    cfg = spec.reduced
    model = get_model(cfg)
    params = model.init(jax.random.key(2))
    B, S_cache = 2, 16
    if cfg.family == "encdec":
        frames = jax.random.normal(jax.random.key(3), (B, 4, cfg.d_model))
        state = model.init_decode_state((params, frames), B, S_cache)
    else:
        state = model.init_decode_state(params, B, S_cache)
    tok = jnp.ones((B, 1), jnp.int32)
    new_state, logits = model.decode_step(params, state, tok)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: logits not finite"
    # cache length advanced
    assert int(new_state[-1][0]) == 1 or int(new_state.cache_len[0]) == 1


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_param_count(arch):
    """Full configs hit their nameplate parameter counts (±20%)."""
    expected = {
        "seamless-m4t-medium": 0.75e9,   # medium ≈ 0.7-0.9B with 256k vocab
        "chameleon-34b": 34e9,
        "qwen3-moe-235b-a22b": 235e9,
        "llama4-maverick-400b-a17b": 400e9,
        "minicpm3-4b": 4e9,
        "qwen1.5-4b": 4e9,
        "qwen3-32b": 32e9,
        "starcoder2-15b": 15e9,
        "rwkv6-1.6b": 1.6e9,
        "zamba2-2.7b": 2.7e9,
    }[arch]
    n = get_arch(arch).config.param_count()
    assert 0.7 * expected <= n <= 1.45 * expected, f"{arch}: {n:,} params"


@pytest.mark.parametrize("arch", ["qwen3-moe-235b-a22b", "llama4-maverick-400b-a17b"])
def test_moe_active_params(arch):
    cfg = get_arch(arch).config
    active = cfg.active_param_count()
    total = cfg.param_count()
    assert active < 0.25 * total
    expected = {"qwen3-moe-235b-a22b": 22e9, "llama4-maverick-400b-a17b": 17e9}[arch]
    assert 0.6 * expected <= active <= 1.6 * expected, f"{arch}: {active:,} active"


def test_input_specs_all_cells():
    """Every non-skipped (arch × shape) cell has well-formed input specs."""
    for arch in ALL_ARCHS:
        spec = get_arch(arch)
        cfg = spec.config
        for shape in SHAPES.values():
            if spec.skip_reason(shape.name):
                continue
            tree = input_specs(cfg, shape)
            for leaf in jax.tree.leaves(tree):
                assert all(dim > 0 for dim in leaf.shape)


def test_skip_reasons():
    """long_500k skips exactly the 8 pure full-attention archs."""
    skipped = [a for a in ALL_ARCHS if get_arch(a).skip_reason("long_500k")]
    assert len(skipped) == 8
    assert "rwkv6-1.6b" not in skipped and "zamba2-2.7b" not in skipped
    for a in ALL_ARCHS:
        assert get_arch(a).skip_reason("train_4k") is None
