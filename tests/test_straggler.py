"""Straggler-detection + elastic replica-planning unit tests (DESIGN.md §13).

The ISSUE-6 StepTimer bug: the baseline froze on the first 8 recorded
steps, which include jit compile time — an inflated baseline meant real
stragglers were never flagged.  These suites pin the fixed behavior:
warmup records are discarded, the baseline seeds from clean samples and
then tracks slowly, sudden sustained slowdowns flag, gradual degradation
trips the checkpoint advice, and benign slow drift does neither.

Pure python (no jax) — runs in the hermetic job too.
"""
import pytest

from repro.ft.elastic import plan_serve_rescale
from repro.ft.straggler import FleetMonitor, StepTimer, StragglerConfig

pytestmark = pytest.mark.hermetic

CFG = StragglerConfig()


def feed(timer, xs):
    for x in xs:
        timer.record(x)


def test_compile_spike_does_not_inflate_baseline():
    t = StepTimer(CFG)
    # 4 compile-spike steps (the seed bug folded these into the baseline),
    # then steady state
    feed(t, [5.0, 5.0, 4.0, 3.0])
    feed(t, [0.1] * 20)
    assert t.baseline == pytest.approx(0.1, rel=0.2)
    assert not t.is_straggling()
    assert t.recommendation() is None
    # a real sustained 5x slowdown must now flag (with the frozen inflated
    # baseline of the seed code, 0.5s steps sat *below* baseline forever)
    feed(t, [0.5] * 8)
    assert t.is_straggling()
    assert t.recommendation() is not None


def test_warmup_records_never_enter_window():
    t = StepTimer(CFG)
    feed(t, [100.0] * CFG.warmup)
    assert len(t.times) == 0 and t.baseline is None
    feed(t, [1.0] * CFG.baseline_min)
    assert t.baseline == pytest.approx(1.0)


def test_gradual_degradation_trips_checkpoint_advice():
    t = StepTimer(CFG)
    feed(t, [1.0] * (CFG.warmup + CFG.baseline_min))
    # 3x degradation over 60 steps: the slow EMA baseline lags far enough
    # behind that the trend check fires
    feed(t, [1.0 + 2.0 * i / 60 for i in range(1, 61)])
    assert t.recommendation() == "checkpoint_now"


def test_slow_benign_drift_stays_quiet():
    t = StepTimer(CFG)
    feed(t, [1.0] * (CFG.warmup + CFG.baseline_min))
    # +20% over 300 steps: the baseline tracks it; neither check may fire
    feed(t, [1.0 + 0.2 * i / 300 for i in range(1, 301)])
    assert not t.is_straggling()
    assert t.recommendation() is None


def test_fleet_monitor_flags_the_slow_worker():
    fm = FleetMonitor(4, CFG)
    # healthy fleet, then worker 2 degrades 20x (dying NIC, hot neighbor …)
    for step in range(24):
        for w in range(4):
            fm.record(w, 0.1)
    for step in range(12):
        for w in range(4):
            fm.record(w, 2.0 if w == 2 else 0.1)
    assert fm.stragglers() == [2]
    # the degraded worker's own timer also notices (fleet-relative and
    # self-relative detection agree on a degradation)
    assert 2 in fm.recommendations()


def test_fleet_monitor_uniform_fleet_is_clean():
    fm = FleetMonitor(4, CFG)
    for step in range(24):
        for w in range(4):
            fm.record(w, 0.1 + 0.001 * w)  # benign per-host jitter
    assert fm.stragglers() == []


def test_plan_serve_rescale_preserves_shard_axis():
    p = plan_serve_rescale(8, 4)
    assert p.mesh_shape == (2, 4) and p.axis_names == ("replica", "shard")
    assert p.dropped_pods == 0
    # lost a device: the partial replica group is shed
    p = plan_serve_rescale(7, 4)
    assert p.mesh_shape == (1, 4) and p.dropped_pods == 3


def test_plan_serve_rescale_rejects_impossible_fleets():
    with pytest.raises(ValueError):
        plan_serve_rescale(3, 4)  # can't hold one full replica
    with pytest.raises(ValueError):
        plan_serve_rescale(0, 4)
    with pytest.raises(ValueError):
        plan_serve_rescale(8, 0)
