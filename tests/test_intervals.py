"""Property tests for the interval algebra (paper §2.1, Def. 3.1 conditions)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import intervals as iv
import pytest

pytestmark = pytest.mark.hermetic  # runs in the no-hypothesis CI job

finite = st.floats(-10, 10, allow_nan=False, allow_infinity=False, width=32)


def mk(l, r):
    lo, hi = min(l, r), max(l, r)
    return jnp.asarray([lo, hi], jnp.float32)


@settings(max_examples=50, deadline=None)
@given(finite, finite, finite, finite)
def test_hull_contains_both(a, b, c, d):
    x, y = mk(a, b), mk(c, d)
    h = iv.hull(x, y)
    assert bool(iv.contains(h, x)) and bool(iv.contains(h, y))


@settings(max_examples=50, deadline=None)
@given(finite, finite, finite, finite)
def test_intersection_subset(a, b, c, d):
    x, y = mk(a, b), mk(c, d)
    inter = iv.intersection(x, y)
    if not bool(iv.is_empty(inter)):
        assert bool(iv.contains(x, inter)) and bool(iv.contains(y, inter))


@settings(max_examples=50, deadline=None)
@given(finite, finite, finite, finite)
def test_if_predicate_matches_definition(a, b, c, d):
    obj, q = mk(a, b), mk(c, d)
    expect = (q[0] <= obj[0]) and (obj[1] <= q[1])
    assert bool(iv.predicate(iv.Semantics.IF, obj, q)) == expect


@settings(max_examples=50, deadline=None)
@given(finite, finite, finite, finite)
def test_is_predicate_matches_definition(a, b, c, d):
    obj, q = mk(a, b), mk(c, d)
    expect = (obj[0] <= q[0]) and (q[1] <= obj[1])
    assert bool(iv.predicate(iv.Semantics.IS, obj, q)) == expect


@settings(max_examples=50, deadline=None)
@given(finite, finite, finite)
def test_rf_reduction(a, ql, qr):
    """RFANN == IFANN with point object intervals (§2.1)."""
    obj = mk(a, a)
    q = mk(ql, qr)
    expect = q[0] <= a <= q[1]
    assert bool(iv.predicate(iv.Semantics.RF, obj, q)) == bool(expect)


@settings(max_examples=50, deadline=None)
@given(finite, finite, finite)
def test_rs_reduction(t, l, r):
    """RSANN == ISANN with point query interval (§2.1)."""
    obj = mk(l, r)
    q = mk(t, t)
    expect = obj[0] <= t <= obj[1]
    assert bool(iv.predicate(iv.Semantics.RS, obj, q)) == bool(expect)


@settings(max_examples=30, deadline=None)
@given(finite, finite, finite, finite, finite, finite)
def test_phi_if_witness_validity(a, b, c, d, e, f):
    """Φ_IF(u,v,w) implies that an IF query admitting u AND v admits w
    (the key step of the heredity proof, Thm 3.5)."""
    iu, ivv, iw = mk(a, b), mk(c, d), mk(e, f)
    if bool(iv.phi_if(iu, ivv, iw)):
        q = iv.hull(iu, ivv)  # smallest query containing both
        assert bool(iv.contains(q, iw))


@settings(max_examples=30, deadline=None)
@given(finite, finite, finite, finite, finite, finite)
def test_phi_is_witness_validity(a, b, c, d, e, f):
    """Φ_IS(u,v,w) implies any IS query stabbing u AND v stabs w."""
    iu, ivv, iw = mk(a, b), mk(c, d), mk(e, f)
    if bool(iv.phi_is(iu, ivv, iw)):
        inter = iv.intersection(iu, ivv)
        assert not bool(iv.is_empty(inter))
        assert bool(iv.contains(iw, inter))


@settings(max_examples=40, deadline=None)
@given(finite, finite, finite, finite)
def test_hull_identities(a, b, c, d):
    """Hull is idempotent, commutative, and the *least* upper bound."""
    x, y = mk(a, b), mk(c, d)
    h = iv.hull(x, y)
    assert bool(jnp.array_equal(iv.hull(x, x), x))
    assert bool(jnp.array_equal(h, iv.hull(y, x)))
    # least: any interval containing both x and y contains hull(x, y)
    z = iv.hull(h, mk(min(a, c) - 1.0, max(b, d) + 1.0))
    assert bool(iv.contains(z, h))


@settings(max_examples=40, deadline=None)
@given(finite, finite, finite, finite)
def test_intersection_identities(a, b, c, d):
    """Intersection is idempotent, commutative, and the greatest lower bound."""
    x, y = mk(a, b), mk(c, d)
    inter = iv.intersection(x, y)
    assert bool(jnp.array_equal(iv.intersection(x, x), x))
    assert bool(jnp.array_equal(inter, iv.intersection(y, x)))
    if not bool(iv.is_empty(inter)):
        # greatest: any interval inside both x and y is inside x ∩ y
        assert bool(iv.contains(x, inter)) and bool(iv.contains(y, inter))
        assert bool(iv.contains(iv.hull(x, y), inter))


@settings(max_examples=40, deadline=None)
@given(finite, finite, finite, finite, finite, finite)
def test_contains_partial_order(a, b, c, d, e, f):
    """⊆ is reflexive and transitive on intervals."""
    x, y, z = mk(a, b), mk(c, d), mk(e, f)
    assert bool(iv.contains(x, x))
    if bool(iv.contains(y, x)) and bool(iv.contains(z, y)):
        assert bool(iv.contains(z, x))


@settings(max_examples=40, deadline=None)
@given(finite, finite, finite, finite, finite, finite)
def test_phi_witness_duality(a, b, c, d, e, f):
    """Φ_IF and Φ_IS are order-duals (Def. 3.1): Φ_IF bounds ``w`` above by
    the *join* (hull) of u, v; Φ_IS bounds it below by the *meet*
    (intersection), guarded on the meet existing."""
    iu, ivv, iw = mk(a, b), mk(c, d), mk(e, f)
    assert bool(iv.phi_if(iu, ivv, iw)) == bool(iv.contains(iv.hull(iu, ivv), iw))
    inter = iv.intersection(iu, ivv)
    expect_is = (not bool(iv.is_empty(inter))) and bool(iv.contains(iw, inter))
    assert bool(iv.phi_is(iu, ivv, iw)) == expect_is


@settings(max_examples=40, deadline=None)
@given(finite, finite, finite)
def test_phi_duality_on_points(u, v, w):
    """On point intervals both witness conditions degenerate to betweenness:
    Φ_IF([u],[v],[w]) ⇔ min(u,v) ≤ w ≤ max(u,v) ⇔ Φ_IS([w'],[v'],[u'])-style
    meet condition with the roles of w and (u,v) swapped."""
    pu, pv, pw = mk(u, u), mk(v, v), mk(w, w)
    u32, v32, w32 = np.float32(u), np.float32(v), np.float32(w)
    between = bool(min(u32, v32) <= w32 <= max(u32, v32))
    assert bool(iv.phi_if(pu, pv, pw)) == between
    # dual: point meets only exist for equal points, so Φ_IS degenerates to
    # equality — the strictest instance of the meet lower bound
    assert bool(iv.phi_is(pu, pv, pw)) == bool(u32 == v32 == w32)


@settings(max_examples=40, deadline=None)
@given(finite, finite, finite)
def test_rf_if_equivalence_degenerate(a, ql, qr):
    """predicate(RF) ≡ predicate(IF) — RF is IF after the point-interval
    reduction (§2.1), for *any* object interval, degenerate or not."""
    q = mk(ql, qr)
    for obj in (mk(a, a), mk(a, a + 1.0)):
        assert bool(iv.predicate(iv.Semantics.RF, obj, q)) == \
            bool(iv.predicate(iv.Semantics.IF, obj, q))


@settings(max_examples=40, deadline=None)
@given(finite, finite, finite)
def test_rs_is_equivalence_degenerate(t, l, r):
    """predicate(RS) ≡ predicate(IS) under the point-query reduction."""
    obj = mk(l, r)
    for q in (mk(t, t), mk(t, t + 1.0)):
        assert bool(iv.predicate(iv.Semantics.RS, obj, q)) == \
            bool(iv.predicate(iv.Semantics.IS, obj, q))


@settings(max_examples=40, deadline=None)
@given(finite, finite, finite, finite)
def test_query_valid_mask_matches_predicate(a, b, ql, qr):
    obj = jnp.stack([mk(a, b), mk(b, a)], axis=0)
    q = mk(ql, qr)
    for sem in (iv.Semantics.IF, iv.Semantics.IS):
        m = iv.query_valid_mask(sem, obj, q)
        for row in range(2):
            assert bool(m[row]) == bool(iv.predicate(sem, obj[row], q))


def test_uniform_interval_model():
    import jax

    ints = iv.sample_uniform_intervals(jax.random.key(0), 1000)
    assert ints.shape == (1000, 2)
    assert bool(jnp.all(ints[:, 0] <= ints[:, 1]))
    assert float(ints.min()) >= 0.0 and float(ints.max()) <= 1.0
