"""Property tests for the interval algebra (paper §2.1, Def. 3.1 conditions)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import intervals as iv

finite = st.floats(-10, 10, allow_nan=False, allow_infinity=False, width=32)


def mk(l, r):
    lo, hi = min(l, r), max(l, r)
    return jnp.asarray([lo, hi], jnp.float32)


@settings(max_examples=50, deadline=None)
@given(finite, finite, finite, finite)
def test_hull_contains_both(a, b, c, d):
    x, y = mk(a, b), mk(c, d)
    h = iv.hull(x, y)
    assert bool(iv.contains(h, x)) and bool(iv.contains(h, y))


@settings(max_examples=50, deadline=None)
@given(finite, finite, finite, finite)
def test_intersection_subset(a, b, c, d):
    x, y = mk(a, b), mk(c, d)
    inter = iv.intersection(x, y)
    if not bool(iv.is_empty(inter)):
        assert bool(iv.contains(x, inter)) and bool(iv.contains(y, inter))


@settings(max_examples=50, deadline=None)
@given(finite, finite, finite, finite)
def test_if_predicate_matches_definition(a, b, c, d):
    obj, q = mk(a, b), mk(c, d)
    expect = (q[0] <= obj[0]) and (obj[1] <= q[1])
    assert bool(iv.predicate(iv.Semantics.IF, obj, q)) == expect


@settings(max_examples=50, deadline=None)
@given(finite, finite, finite, finite)
def test_is_predicate_matches_definition(a, b, c, d):
    obj, q = mk(a, b), mk(c, d)
    expect = (obj[0] <= q[0]) and (q[1] <= obj[1])
    assert bool(iv.predicate(iv.Semantics.IS, obj, q)) == expect


@settings(max_examples=50, deadline=None)
@given(finite, finite, finite)
def test_rf_reduction(a, ql, qr):
    """RFANN == IFANN with point object intervals (§2.1)."""
    obj = mk(a, a)
    q = mk(ql, qr)
    expect = q[0] <= a <= q[1]
    assert bool(iv.predicate(iv.Semantics.RF, obj, q)) == bool(expect)


@settings(max_examples=50, deadline=None)
@given(finite, finite, finite)
def test_rs_reduction(t, l, r):
    """RSANN == ISANN with point query interval (§2.1)."""
    obj = mk(l, r)
    q = mk(t, t)
    expect = obj[0] <= t <= obj[1]
    assert bool(iv.predicate(iv.Semantics.RS, obj, q)) == bool(expect)


@settings(max_examples=30, deadline=None)
@given(finite, finite, finite, finite, finite, finite)
def test_phi_if_witness_validity(a, b, c, d, e, f):
    """Φ_IF(u,v,w) implies that an IF query admitting u AND v admits w
    (the key step of the heredity proof, Thm 3.5)."""
    iu, ivv, iw = mk(a, b), mk(c, d), mk(e, f)
    if bool(iv.phi_if(iu, ivv, iw)):
        q = iv.hull(iu, ivv)  # smallest query containing both
        assert bool(iv.contains(q, iw))


@settings(max_examples=30, deadline=None)
@given(finite, finite, finite, finite, finite, finite)
def test_phi_is_witness_validity(a, b, c, d, e, f):
    """Φ_IS(u,v,w) implies any IS query stabbing u AND v stabs w."""
    iu, ivv, iw = mk(a, b), mk(c, d), mk(e, f)
    if bool(iv.phi_is(iu, ivv, iw)):
        inter = iv.intersection(iu, ivv)
        assert not bool(iv.is_empty(inter))
        assert bool(iv.contains(iw, inter))


def test_uniform_interval_model():
    import jax

    ints = iv.sample_uniform_intervals(jax.random.key(0), 1000)
    assert ints.shape == (1000, 2)
    assert bool(jnp.all(ints[:, 0] <= ints[:, 1]))
    assert float(ints.min()) >= 0.0 and float(ints.max()) <= 1.0
