"""Multi-device tests, run in a subprocess with 8 fake CPU devices (the
device count must be fixed before jax initializes, so these can't share the
main pytest process which other tests run single-device)."""
import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent


def run_sub(code: str, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=timeout,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_sharded_search_and_ring_knn():
    run_sub(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import intervals as iv, brute_force, recall
from repro.core.build import UGConfig
from repro.core.search import SearchResult
from repro.core.sharded import (build_sharded_index_host, shard_index,
                                make_sharded_search_fn, make_ring_knn_fn)
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))
k1, k2, k3, k4 = jax.random.split(jax.random.key(0), 4)
n, d = 1200, 12
x = np.asarray(jax.random.normal(k1, (n, d)))
ints = np.asarray(iv.sample_uniform_intervals(k2, n))
cfg = UGConfig(ef_spatial=16, ef_attribute=32, max_edges_if=16, max_edges_is=16,
               iterations=2, repair_width=8, exact_spatial=True, block=512)
xs, its, nbs, sts, gid = build_sharded_index_host(x, ints, 4, cfg)
sidx = shard_index(mesh, ("data",), xs, its, nbs, sts, gid)
nq = 16
qv = jax.random.normal(k3, (nq, d))
c = jax.random.uniform(k4, (nq, 1))
qi = jnp.concatenate([jnp.maximum(c-0.3,0), jnp.minimum(c+0.3,1)], axis=1)
fn = make_sharded_search_fn(mesh, index_axes=("data",), sem=iv.Semantics.IF, ef=48, k=10)
ids, dist = fn(sidx, qv, qi)
gt = brute_force(jnp.asarray(x), jnp.asarray(ints), qv, qi, sem=iv.Semantics.IF, k=10)
r = recall(SearchResult(ids, dist, None), gt)
assert r >= 0.9, r

# mixed runtime-semantics sharded search: one program, per-query flags;
# rows must equal the corresponding static-semantics program bit-for-bit
fnm = make_sharded_search_fn(mesh, index_axes=("data",), sem=iv.Semantics.IF,
                             ef=48, k=10, mixed=True)
flags = jnp.asarray([iv.FLAG_IF, iv.FLAG_IS] * (nq // 2), jnp.int32)
ids_m, dist_m = fnm(sidx, qv, qi, flags)
fn_is = make_sharded_search_fn(mesh, index_axes=("data",), sem=iv.Semantics.IS, ef=48, k=10)
ids_is, dist_is = fn_is(sidx, qv, qi)
f_np = np.asarray(flags)
for sel, ref_ids, ref_d in ((f_np == iv.FLAG_IF, ids, dist),
                            (f_np == iv.FLAG_IS, ids_is, dist_is)):
    assert np.array_equal(np.asarray(ids_m)[sel], np.asarray(ref_ids)[sel])
    assert np.array_equal(np.asarray(dist_m)[sel], np.asarray(ref_d)[sel])

ring = make_ring_knn_fn(mesh, axis="data", k=8)
row = NamedSharding(mesh, P(("data",)))
ri, rd = ring(jax.device_put(xs, row), jax.device_put(gid, row))
ri_np = np.asarray(ri)
gid_np = np.asarray(gid)
for local_row in (0, 7, 131):
    g = gid_np[local_row]
    if g < 0: continue
    dall = ((x - x[g])**2).sum(1); dall[g] = np.inf
    assert set(ri_np[local_row].tolist()) == set(np.argsort(dall)[:8].tolist())
print("sharded search + ring knn OK", r)
"""
    )


def test_ep_moe_and_compression():
    run_sub(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models import ModelConfig, shard_ctx
from repro.models import moe as moe_lib
from repro.models.common import ParamBuilder
from repro.launch.mesh import make_mesh
from repro.distributed import compressed_psum, init_ef

# EP MoE == local MoE
cfg = ModelConfig(family="decoder", n_layers=1, d_model=16, n_heads=2, n_kv_heads=2,
                  d_ff=32, vocab=32, moe=True, n_experts=8, top_k=2, moe_d_ff=32,
                  n_shared_experts=1, capacity_factor=16.0, dtype=jnp.float32)
b = ParamBuilder(cfg, "init", key=jax.random.key(0))
p = moe_lib.build_moe_params(cfg, b, prefix_layers=False)
x = jax.random.normal(jax.random.key(7), (4, 8, 16))
y0, a0 = moe_lib._moe_ffn_local(cfg, p, x)
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
with shard_ctx.use_mesh(mesh):
    y1, a1 = jax.jit(lambda pp, xx: moe_lib.moe_ffn(cfg, pp, xx))(p, x)
assert float(jnp.max(jnp.abs(y0 - y1))) < 1e-4
assert abs(float(a0) - float(a1)) < 1e-6

# compressed psum with error feedback ~= plain psum
mesh2 = make_mesh((8,), ("data",))
g = {"w": jax.random.normal(jax.random.key(1), (8, 512))}
ef = init_ef({"w": g["w"][0]})
def local(gw):
    grads = {"w": gw[0]}
    mean_g, new_ef = compressed_psum(grads, init_ef(grads), "data")
    return mean_g["w"][None]
from repro.compat import shard_map
fn = shard_map(local, mesh=mesh2, in_specs=(P("data", None),),
               out_specs=P("data", None), check_vma=False)
out = fn(g["w"][:, None, :].reshape(8, 1, 512))
expect = jnp.mean(g["w"], axis=0)
err = float(jnp.max(jnp.abs(out[0] - expect)))
rel = err / float(jnp.max(jnp.abs(expect)))
assert rel < 0.05, rel   # int8 quantization noise bound
print("EP MoE + compression OK", rel)
"""
    )


def test_ring_collectives():
    run_sub(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.distributed import ring_all_gather, ring_reduce_scatter
from repro.launch.mesh import make_mesh

mesh = make_mesh((8,), ("data",))
x = jax.random.normal(jax.random.key(0), (8, 4))

def ag(xl):
    size, blocks = ring_all_gather(xl[0], "data")
    return blocks[None]
out = shard_map(ag, mesh=mesh, in_specs=(P("data", None),),
                out_specs=P("data", None, None), check_vma=False)(x[:, None, :].reshape(8,1,4))
# rank r's ring order starts at its own shard going backwards around the ring
me0 = np.asarray(out[0]).reshape(8, 4)
assert np.allclose(me0[0], np.asarray(x[0]))
assert set(map(tuple, me0.round(4).tolist())) == set(map(tuple, np.asarray(x).round(4).tolist()))

y = jax.random.normal(jax.random.key(1), (8, 8, 4))  # per rank: (8 chunks, 4)
def rs(yl):
    return ring_reduce_scatter(yl[0], "data")[None]
out2 = shard_map(rs, mesh=mesh, in_specs=(P("data", None, None),),
                 out_specs=P("data", None), check_vma=False)(y)
expect = jnp.sum(y, axis=0)  # sum over ranks, chunk r to rank r
np.testing.assert_allclose(np.asarray(out2), np.asarray(expect), atol=1e-5)
print("ring collectives OK")
"""
    )


def test_elastic_restore_across_meshes(tmp_path):
    run_sub(
        f"""
import jax, jax.numpy as jnp, numpy as np
from repro.models import ModelConfig, get_model
from repro.train import AdamWConfig, optim
from repro.ckpt import save
from repro.ft import resume
from repro.launch.mesh import make_mesh

cfg = ModelConfig(family="decoder", n_layers=2, d_model=32, n_heads=4, n_kv_heads=4,
                  d_ff=64, vocab=64, dtype=jnp.float32)
model = get_model(cfg)
params = model.init(jax.random.key(0))
ocfg = AdamWConfig()
ostate = optim.init(ocfg, params)
save(r'{tmp_path}', 7, params, ostate, data_cursor=7)

# restore onto an 8-device mesh (checkpoint was written single-device)
mesh = make_mesh((4, 2), ("data", "model"))
rp, ro, meta = resume(r'{tmp_path}', model, ostate, mesh)
assert meta["data_cursor"] == 7
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(rp)):
    assert np.allclose(np.asarray(a), np.asarray(b))
# leaves are actually device-sharded now
shardings = {{str(l.sharding) for l in jax.tree.leaves(rp)}}
assert any("model" in s or "data" in s for s in shardings)
print("elastic restore OK")
"""
    )


def test_serve_fleet_monitor_on_sharded_index():
    """Straggler probing + elastic replica planning over a real sharded
    store (DESIGN.md §13): per-shard probe callables reproduce the
    shard_map-local search (their merged top-k covers the global answer),
    a degrading shard is flagged, and the degraded replica plan sheds that
    shard's device group."""
    run_sub(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.core import intervals as iv
from repro.core.build import UGConfig
from repro.core.sharded import (build_sharded_index_host, shard_index,
                                make_sharded_search_fn, make_shard_probe_fns)
from repro.launch.mesh import make_mesh
from repro.serve import FleetServeMonitor
from repro.ft.straggler import StragglerConfig

mesh = make_mesh((4, 2), ("data", "model"))
k1, k2, k3, k4 = jax.random.split(jax.random.key(0), 4)
n, d, S = 1200, 12, 4
x = np.asarray(jax.random.normal(k1, (n, d)))
ints = np.asarray(iv.sample_uniform_intervals(k2, n))
cfg = UGConfig(ef_spatial=16, ef_attribute=32, max_edges_if=16, max_edges_is=16,
               iterations=2, repair_width=8, exact_spatial=True, block=512)
xs, its, nbs, sts, gid = build_sharded_index_host(x, ints, S, cfg)
sidx = shard_index(mesh, ("data",), xs, its, nbs, sts, gid)

nq, k = 8, 10
qv = jax.random.normal(k3, (nq, d))
c = jax.random.uniform(k4, (nq, 1))
qi = jnp.concatenate([jnp.maximum(c-0.3,0), jnp.minimum(c+0.3,1)], axis=1)
flags = jnp.asarray([iv.FLAG_IF if i % 2 else iv.FLAG_IS for i in range(nq)],
                    jnp.int32)

# probe fns run the same per-shard program the shard_map step runs: the
# union of per-shard top-k must cover the global sharded answer
probe_fns = make_shard_probe_fns(sidx, S, ef=48, k=k)
per_shard = [fn(qv, qi, flags) for fn in probe_fns]
fn_g = make_sharded_search_fn(mesh, index_axes=("data",), sem=iv.Semantics.IF,
                              ef=48, k=k, mixed=True)
gids, gdist = fn_g(sidx, qv, qi, flags)
union_ids = np.concatenate([np.asarray(p[0]) for p in per_shard], axis=1)
for q in range(nq):
    got = set(np.asarray(gids)[q].tolist()) - {-1}
    cover = set(union_ids[q].tolist())
    assert got <= cover, (q, got - cover)

# fleet health: warm the timers with real probe timings, then shard 2
# degrades 20x — it must be flagged and the degraded plan must shed its
# device group while keeping the shard axis intact
scfg = StragglerConfig()
fm = FleetServeMonitor(n_shards=S, n_devices=8, cfg=scfg)
for _ in range(scfg.warmup + scfg.baseline_min + scfg.recent):
    times = fm.probe(probe_fns, qv, qi, flags)
    assert len(times) == S and all(t > 0 for t in times)
base = float(np.median([np.median(t._recent()) for t in fm.fleet.timers]))
for _ in range(2 * scfg.recent):
    for s in range(S):
        fm.record(s, 20.0 * base if s == 2 else base)
rep = fm.report()
assert rep["stragglers"] == [2], rep["stragglers"]
assert rep["recommendations"].get(2) == "checkpoint_now"
assert rep["plan"].mesh_shape == (2, S)
assert rep["degraded_plan"] is not None
assert rep["degraded_plan"].mesh_shape == (1, S)
assert rep["degraded_plan"].dropped_pods == 2
print("fleet monitor OK")
"""
    )
