"""Bitonic partial-merge kernel: backends vs the lexsort oracle (ref.py).

The merge is the one component where the ``pallas`` and ``xla`` search
backends could diverge, so the contract is strict: *bit-identical* outputs
(not set-equal) across both backends and the oracle, including inf padding,
duplicate keys, and non-power-of-two candidate widths.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.beam_merge import PAD_PAYLOAD, merge_comparator_count, next_pow2


def make_case(seed, B, E, L, inf_frac=0.3, dup=True):
    rng = np.random.default_rng(seed)
    pool = [0.25, 0.5, 1.0, 2.0] if dup else list(rng.uniform(0, 4, 64))
    bd = rng.choice(pool, size=(B, E)).astype(np.float32)
    bd[rng.uniform(size=(B, E)) < inf_frac] = np.inf
    bp = rng.integers(0, 500, (B, E)).astype(np.int32) << 1
    # beam invariant: ascending in the (d, p) total order, inf slots padded
    bp = np.where(np.isfinite(bd), bp, PAD_PAYLOAD).astype(np.int32)
    o = np.lexsort((bp, bd), axis=-1)
    bd = np.take_along_axis(bd, o, -1)
    bp = np.take_along_axis(bp, o, -1)
    cd = rng.choice(pool + [np.inf], size=(B, L)).astype(np.float32)
    cp = np.where(np.isfinite(cd), rng.integers(0, 500, (B, L)) << 1,
                  PAD_PAYLOAD).astype(np.int32)
    return map(jnp.asarray, (bd, bp, cd, cp))


@pytest.mark.parametrize("B,E,L", [(1, 8, 8), (5, 16, 48), (9, 64, 128),
                                   (3, 64, 5), (2, 8, 200), (7, 32, 32)])
def test_backends_match_oracle_bitwise(B, E, L):
    bd, bp, cd, cp = make_case(B * 100 + E + L, B, E, L)
    rd, rp = ref.beam_merge(bd, bp, cd, cp)
    for backend in ("xla", "pallas"):
        od, op = ops.beam_merge(bd, bp, cd, cp, backend=backend)
        assert np.array_equal(np.asarray(od), np.asarray(rd)), backend
        assert np.array_equal(np.asarray(op), np.asarray(rp)), backend


def test_output_sorted_and_is_topE_of_union():
    bd, bp, cd, cp = make_case(7, 4, 32, 64, inf_frac=0.1)
    od, op = ops.beam_merge(bd, bp, cd, cp, backend="xla")
    od, op = np.asarray(od), np.asarray(op)
    # ascending under (d, p)
    for r in range(4):
        pairs = list(zip(od[r], op[r]))
        assert pairs == sorted(pairs)
    # multiset == E smallest of the union
    all_d = np.concatenate([np.asarray(bd), np.asarray(cd)], axis=-1)
    all_p = np.concatenate([np.asarray(bp), np.asarray(cp)], axis=-1)
    for r in range(4):
        union = sorted(zip(all_d[r], all_p[r]))[:32]
        assert sorted(zip(od[r], op[r])) == union


def test_all_inf_candidates_is_noop():
    bd, bp, cd, cp = make_case(3, 6, 16, 32)
    cd = jnp.full_like(cd, jnp.inf)
    cp = jnp.full_like(cp, PAD_PAYLOAD)
    od, op = ops.beam_merge(bd, bp, cd, cp, backend="xla")
    assert np.array_equal(np.asarray(od), np.asarray(bd))
    assert np.array_equal(np.asarray(op), np.asarray(bp))


def test_non_pow2_beam_rejected():
    bd = jnp.zeros((2, 12), jnp.float32)
    bp = jnp.full((2, 12), PAD_PAYLOAD, jnp.int32)
    with pytest.raises(ValueError):
        from repro.kernels import beam_merge as bm
        bm.beam_merge(bd, bp, bd, bp, interpret=True)


def test_cost_model_fused_beats_legacy():
    """The acceptance-criterion arithmetic: fewer merge comparator ops per
    expansion than the legacy full argsort, for every practical config."""
    for ef in (16, 32, 48, 64, 96, 128):
        for M in (8, 16, 32, 64):
            legacy = merge_comparator_count(ef, M, fused=False)
            for W in (1, 2, 4, 8):
                fused = merge_comparator_count(ef, M, width=W, fused=True)
                assert fused < legacy, (ef, M, W, fused, legacy)


def test_next_pow2():
    assert [next_pow2(v) for v in (1, 2, 3, 5, 8, 9, 128)] == \
        [1, 2, 4, 8, 8, 16, 128]
