"""Tiny vendored stand-in for ``hypothesis`` (used when the real package is
absent — e.g. the hermetic CI container).

Only the surface the repo's property tests use is provided: ``given``,
``settings`` and ``strategies.floats`` / ``strategies.integers``.  ``given``
runs the test body over a deterministic sample: all corner combinations of
each strategy's boundary values plus seeded pseudo-random draws, honoring
``settings(max_examples=...)``.  It is *not* hypothesis — no shrinking, no
database — but it keeps the invariant tests executable (and the suite
collectable) with zero dependencies.  With hypothesis installed (see
requirements-dev.txt) the real library is used instead; tests/conftest.py
registers this module in ``sys.modules`` only on ImportError.
"""
from __future__ import annotations

import functools
import inspect
import itertools
import random
import struct


def _f32(v: float) -> float:
    """Round to the nearest float32-representable value (width=32 contract:
    real hypothesis only emits representable floats, and tests rely on it)."""
    return struct.unpack("f", struct.pack("f", v))[0]


class _Strategy:
    def __init__(self, corners, draw):
        self.corners = corners      # boundary examples, always exercised
        self.draw = draw            # rng -> random example


def floats(min_value=0.0, max_value=1.0, allow_nan=False, allow_infinity=False,
           width=64, **_ignored):
    lo, hi = float(min_value), float(max_value)
    conv = _f32 if width == 32 else float
    corners = [conv(lo), conv(hi), conv((lo + hi) / 2.0)]
    return _Strategy(corners, lambda rng: conv(rng.uniform(lo, hi)))


def integers(min_value=0, max_value=100, **_ignored):
    lo, hi = int(min_value), int(max_value)
    corners = [lo, hi]
    return _Strategy(corners, lambda rng: rng.randint(lo, hi))


class strategies:
    floats = staticmethod(floats)
    integers = staticmethod(integers)


def settings(max_examples: int = 50, deadline=None, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strats):
    def deco(fn):
        @functools.wraps(fn)
        def runner(*fixture_args, **fixture_kw):
            # @settings sits *above* @given in this repo, so the attribute
            # lands on the outer wrapper — read it there at call time.
            max_examples = getattr(runner, "_fallback_max_examples",
                                   getattr(fn, "_fallback_max_examples", 50))
            rng = random.Random(f"{fn.__module__}.{fn.__name__}")
            cases = list(itertools.islice(
                itertools.product(*(s.corners for s in strats)), max_examples
            ))
            while len(cases) < max_examples:
                cases.append(tuple(s.draw(rng) for s in strats))
            for case in cases:
                fn(*fixture_args, *case, **fixture_kw)
        # Strategies fill the trailing params; expose only the leading
        # (fixture) params to pytest, else it resolves a/b/c as fixtures.
        params = list(inspect.signature(fn).parameters.values())
        runner.__signature__ = inspect.Signature(params[: len(params) - len(strats)])
        runner.hypothesis_fallback = True
        return runner
    return deco
