"""Substrate tests: optimizer, data determinism, checkpoint/restart, FT."""
import math
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import AsyncCheckpointer, latest_step, restore, save
from repro.data import CorpusConfig, LMDataConfig, host_slice, lm_batch, make_corpus, make_queries
from repro.ft import FleetMonitor, RescalePlan, StepTimer, StragglerConfig, plan_rescale
from repro.models import ModelConfig, get_model
from repro.train import AdamWConfig, make_train_step, optim


# ---------------------------------------------------------------- optimizer
def test_adamw_matches_reference():
    """One AdamW step vs a hand-rolled numpy reference."""
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
                      grad_clip=0.0, warmup_steps=0, total_steps=10,
                      schedule="constant")
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    st = optim.init(cfg, p)
    new_p, new_st, _ = optim.update(cfg, st, p, g)
    m = 0.1 * np.asarray(g["w"])
    v = 0.05 * np.asarray(g["w"]) ** 2
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.95)
    expect = np.asarray(p["w"]) - 1e-2 * (mh / (np.sqrt(vh) + 1e-8) + 0.1 * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, rtol=1e-6)


def test_grad_clip():
    cfg = AdamWConfig(grad_clip=1.0, warmup_steps=0, schedule="constant")
    p = {"w": jnp.zeros(4)}
    g = {"w": jnp.full((4,), 100.0)}
    st = optim.init(cfg, p)
    _, _, stats = optim.update(cfg, st, p, g)
    assert float(stats["grad_norm"]) == pytest.approx(200.0)


def test_lr_schedule_shapes():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, schedule="cosine")
    assert float(optim.lr_at(cfg, jnp.asarray(0))) == 0.0
    assert float(optim.lr_at(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(optim.lr_at(cfg, jnp.asarray(100))) == pytest.approx(0.0, abs=1e-6)


def test_microbatch_equivalence():
    """Grad accumulation over microbatches == single big batch step."""
    cfg = ModelConfig(family="decoder", n_layers=1, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab=64, dtype=jnp.float32, remat=False)
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=0, schedule="constant")
    b = {"tokens": jax.random.randint(jax.random.key(1), (4, 8), 0, 64),
         "labels": jax.random.randint(jax.random.key(2), (4, 8), 0, 64),
         "mask": jnp.ones((4, 8))}
    s1 = make_train_step(model, ocfg, microbatches=1, donate=False)
    s2 = make_train_step(model, ocfg, microbatches=2, donate=False)
    p1, _, m1 = s1(params, optim.init(ocfg, params), b)
    p2, _, m2 = s2(params, optim.init(ocfg, params), b)
    for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=2e-5)


# ---------------------------------------------------------------- data
def test_lm_batch_deterministic():
    cfg = LMDataConfig(vocab=100, batch=4, seq=16, seed=7)
    a = lm_batch(cfg, 5)
    b = lm_batch(cfg, 5)
    c = lm_batch(cfg, 6)
    assert bool(jnp.array_equal(a["tokens"], b["tokens"]))
    assert not bool(jnp.array_equal(a["tokens"], c["tokens"]))
    assert bool(jnp.array_equal(a["labels"][:, :-1], a["tokens"][:, 1:]))


def test_host_slice_partition():
    cfg = LMDataConfig(vocab=100, batch=8, seq=4)
    b = lm_batch(cfg, 0)
    parts = [host_slice(b, i, 4) for i in range(4)]
    recon = jnp.concatenate([p["tokens"] for p in parts])
    assert bool(jnp.array_equal(recon, b["tokens"]))


def test_corpus_and_workloads():
    ccfg = CorpusConfig(n=500, dim=16, seed=3)
    x, ints = make_corpus(ccfg)
    assert x.shape == (500, 16) and ints.shape == (500, 2)
    assert bool(jnp.all(ints[:, 0] <= ints[:, 1]))
    for w in ("uniform", "short", "long", "mixed", "point"):
        qv, qi = make_queries(ccfg, 20, workload=w)
        assert qv.shape == (20, 16)
        assert bool(jnp.all(qi[:, 0] <= qi[:, 1]))
    _, qs = make_queries(ccfg, 20, workload="short")
    _, ql = make_queries(ccfg, 20, workload="long")
    assert float((qs[:, 1] - qs[:, 0]).mean()) < float((ql[:, 1] - ql[:, 0]).mean())


# ---------------------------------------------------------------- checkpoint
def test_ckpt_roundtrip_and_prune(tmp_path):
    cfg = ModelConfig(family="decoder", n_layers=1, d_model=16, n_heads=2,
                      n_kv_heads=2, d_ff=32, vocab=32, dtype=jnp.float32)
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    ocfg = AdamWConfig()
    ostate = optim.init(ocfg, params)
    for s in (1, 2, 3, 4):
        save(tmp_path, s, params, ostate, data_cursor=s, keep=2)
    assert latest_step(tmp_path) == 4
    # pruned to keep=2
    import pathlib

    steps = sorted(p.name for p in pathlib.Path(tmp_path).glob("step_*"))
    assert len(steps) == 2
    rp, ro, meta = restore(tmp_path, params_template=model.shapes(), opt_template=ostate)
    assert meta["data_cursor"] == 4
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(rp)):
        assert bool(jnp.array_equal(a, b))


def test_async_checkpointer(tmp_path):
    cfg = ModelConfig(family="decoder", n_layers=1, d_model=16, n_heads=2,
                      n_kv_heads=2, d_ff=32, vocab=32, dtype=jnp.float32)
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    ac = AsyncCheckpointer(tmp_path, keep=3)
    ac.save(10, params)
    ac.save(20, params)   # waits for the first
    ac.wait()
    assert latest_step(tmp_path) == 20


def test_restart_determinism(tmp_path):
    """Train 6 steps straight == train 3, checkpoint, restore, train 3."""
    cfg = ModelConfig(family="decoder", n_layers=1, d_model=16, n_heads=2,
                      n_kv_heads=2, d_ff=32, vocab=64, dtype=jnp.float32, remat=False)
    model = get_model(cfg)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=0, schedule="constant")
    dcfg = LMDataConfig(vocab=64, batch=4, seq=8)
    step = make_train_step(model, ocfg, donate=False)

    p = model.init(jax.random.key(0))
    o = optim.init(ocfg, p)
    for s in range(6):
        p, o, _ = step(p, o, lm_batch(dcfg, s))
    straight = p

    p = model.init(jax.random.key(0))
    o = optim.init(ocfg, p)
    for s in range(3):
        p, o, _ = step(p, o, lm_batch(dcfg, s))
    save(tmp_path, 3, p, o, data_cursor=3)
    rp, ro, meta = restore(tmp_path, params_template=model.shapes(), opt_template=o)
    p, o = rp, ro
    for s in range(meta["data_cursor"], 6):
        p, o, _ = step(p, o, lm_batch(dcfg, s))
    for a, b in zip(jax.tree.leaves(straight), jax.tree.leaves(p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ---------------------------------------------------------------- fault tol.
def test_straggler_detection():
    t = StepTimer(StragglerConfig(window=16, z_thresh=4.0))
    for _ in range(16):
        t.record(1.0 + np.random.default_rng(0).normal() * 0.01)
    assert not t.is_straggling()
    for _ in range(8):
        t.record(3.0)
    assert t.is_straggling()


def test_fleet_monitor():
    m = FleetMonitor(4)
    rng = np.random.default_rng(1)
    for s in range(20):
        for w in range(4):
            m.record(w, 1.0 + rng.normal() * 0.01 + (2.0 if w == 2 else 0.0))
    assert m.stragglers() == [2]


def test_rescale_plans():
    p = plan_rescale(512, model_parallel=16, pods=2)
    assert p.mesh_shape == (2, 16, 16)
    # half capacity: shrink data per pod (keeps both pods' fast domains)
    p = plan_rescale(256, model_parallel=16, pods=2)
    assert math.prod(p.mesh_shape) == 256 and p.mesh_shape[-1] == 16
    p = plan_rescale(384, model_parallel=16, pods=2)  # lost 8 hosts of pod 2
    assert math.prod(p.mesh_shape) == 384
    with pytest.raises(ValueError):
        plan_rescale(100, model_parallel=16)


def test_compression_ratio():
    from repro.distributed import compression_ratio

    assert compression_ratio(1 << 20) > 1.9
