"""Property tests for the construction pipeline's scatter/dedup primitives.

numpy oracles for the fixed-width building blocks of Alg. 2:

* ``kernels.util.segment_scatter`` — THE shared sort-by-segment + rank
  scatter (``build.scatter_repairs``, ``candidates._reverse_candidates``
  and the delete-repair in-neighbor sets are all this one helper):
  fixed-width truncation keeps the first ``width`` values per segment *in
  scan order*; pairs with a -1 side never leak;
* ``prune._dedup_sorted_by_distance`` — duplicate candidate ids keep the
  *closest* copy; pads and masked duplicates sort to the back as +inf.

Runs under real hypothesis when installed, else the vendored fallback shim
(tests/_hypothesis_fallback.py) registered by conftest.py.
"""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.build import scatter_repairs
from repro.core.candidates import _reverse_candidates
from repro.core.prune import _dedup_sorted_by_distance
from repro.kernels.util import segment_scatter
import pytest

pytestmark = pytest.mark.hermetic  # runs in the no-hypothesis CI job


# ------------------------------------------------------------------ oracles
def scatter_oracle(w_ids, v_ids, n, width):
    out = np.full((n, width), -1, np.int32)
    fill = np.zeros(n, np.int32)
    for w, v in zip(w_ids, v_ids):
        if w < 0 or v < 0 or w >= n:
            continue
        if fill[w] < width:
            out[w, fill[w]] = v
            fill[w] += 1
    return out


def dedup_oracle(cand, dist):
    """Keep the closest copy of each id (ties: first by scan position),
    ascending-distance order, -1/inf pads at the back."""
    best = {}
    for pos, (c, dv) in enumerate(zip(cand, dist)):
        if c < 0:
            continue
        if c not in best or dv < best[c][0]:
            best[c] = (dv, pos)
    order = sorted(best.items(), key=lambda kv: (kv[1][0], kv[1][1]))
    ids = [c for c, _ in order]
    ds = [d for _, (d, _) in order]
    pad = len(cand) - len(ids)
    return ids + [-1] * pad, ds + [np.inf] * pad


# ----------------------------------------------------------------- scatter
@settings(max_examples=40)
@given(st.integers(min_value=1, max_value=60), st.integers(min_value=1, max_value=9),
       st.integers(min_value=0, max_value=10_000))
def test_segment_scatter_matches_oracle(n, width, seed):
    """The shared helper itself, against the numpy oracle (ISSUE-5
    satellite) — segment ids and values drawn independently, including
    out-of-range (>= n is impossible by construction here, -1/-2 pads are
    not)."""
    rng = np.random.default_rng(seed)
    m = int(rng.integers(1, 150))
    seg = rng.integers(-2, n, size=m).astype(np.int32)
    val = rng.integers(-2, 5 * n, size=m).astype(np.int32)
    got = np.asarray(segment_scatter(jnp.asarray(seg), jnp.asarray(val), n, width))
    want = scatter_oracle(seg, val, n, width)
    assert got.shape == (n, width)
    assert np.array_equal(got, want)


@settings(max_examples=20)
@given(st.integers(min_value=1, max_value=40), st.integers(min_value=1, max_value=6),
       st.integers(min_value=0, max_value=10_000))
def test_reverse_candidates_via_segment_scatter(n, r_max, seed):
    """candidates._reverse_candidates == oracle over (dst -> src) pairs."""
    rng = np.random.default_rng(seed)
    k = int(rng.integers(1, 7))
    ids = rng.integers(-1, n, size=(n, k)).astype(np.int32)
    got = np.asarray(_reverse_candidates(jnp.asarray(ids), r_max))
    src = np.broadcast_to(np.arange(n, dtype=np.int32)[:, None], (n, k))
    want = scatter_oracle(ids.reshape(-1), src.reshape(-1), n, r_max)
    assert np.array_equal(got, want)


@settings(max_examples=40)
@given(st.integers(min_value=1, max_value=60), st.integers(min_value=1, max_value=9),
       st.integers(min_value=0, max_value=10_000))
def test_scatter_repairs_matches_oracle(n, width, seed):
    rng = np.random.default_rng(seed)
    m = int(rng.integers(1, 120))
    w_ids = rng.integers(-2, n, size=m).astype(np.int32)
    v_ids = rng.integers(-2, n, size=m).astype(np.int32)
    got = np.asarray(scatter_repairs(jnp.asarray(w_ids), jnp.asarray(v_ids), n, width))
    want = scatter_oracle(w_ids, v_ids, n, width)
    assert got.shape == (n, width)
    assert np.array_equal(got, want)


@settings(max_examples=20)
@given(st.integers(min_value=0, max_value=10_000))
def test_scatter_repairs_truncates_in_scan_order(seed):
    """Over-full witnesses keep exactly the first-by-scan-order offers."""
    rng = np.random.default_rng(seed)
    width, n = 3, 4
    w_ids = np.zeros(10, np.int32)            # every offer targets witness 0
    v_ids = rng.integers(0, n, size=10).astype(np.int32)
    got = np.asarray(scatter_repairs(jnp.asarray(w_ids), jnp.asarray(v_ids), n, width))
    assert got[0].tolist() == v_ids[:width].tolist()
    assert (got[1:] == -1).all()


def test_scatter_repairs_no_pad_leak():
    """(w, v) pairs with any -1 side must never land in a repair slot."""
    w_ids = jnp.asarray([0, -1, 1, 2, -1], jnp.int32)
    v_ids = jnp.asarray([-1, 3, 4, -1, -1], jnp.int32)
    got = np.asarray(scatter_repairs(w_ids, v_ids, 4, 2))
    assert got.tolist() == [[-1, -1], [4, -1], [-1, -1], [-1, -1]]


# -------------------------------------------------------------------- dedup
@settings(max_examples=40)
@given(st.integers(min_value=1, max_value=48), st.integers(min_value=2, max_value=12),
       st.integers(min_value=0, max_value=10_000))
def test_dedup_matches_oracle(C, id_pool, seed):
    rng = np.random.default_rng(seed)
    cand = rng.integers(-2, id_pool, size=C).astype(np.int32)
    dist = rng.uniform(0.0, 4.0, size=C).astype(np.float32)
    got_c, got_d = _dedup_sorted_by_distance(jnp.asarray(cand), jnp.asarray(dist))
    want_c, want_d = dedup_oracle(cand, dist)
    assert np.asarray(got_c).tolist() == want_c
    got_d = np.asarray(got_d)
    assert np.array_equal(got_d[np.isfinite(got_d)],
                          np.asarray(want_d)[np.isfinite(want_d)])
    assert np.isinf(got_d[np.asarray(got_c) < 0]).all()   # pads carry +inf


@settings(max_examples=20)
@given(st.floats(min_value=0.25, max_value=2.0, width=32),
       st.floats(min_value=2.25, max_value=4.0, width=32))
def test_dedup_keeps_closest_copy(d_near, d_far):
    """The same id at two distances survives only at the nearer one."""
    cand = jnp.asarray([7, 3, 7, -1], jnp.int32)
    dist = jnp.asarray([d_far, 3.0, d_near, 0.0], jnp.float32)
    got_c, got_d = _dedup_sorted_by_distance(cand, dist)
    got_c, got_d = np.asarray(got_c), np.asarray(got_d)
    sel = got_c == 7
    assert sel.sum() == 1
    assert got_d[sel][0] == np.float32(d_near)
    assert got_c[-1] == -1 and np.isinf(got_d[-1])        # -1 pad never leaks

    # output is ascending in distance over the live prefix
    live = got_d[np.isfinite(got_d)]
    assert (np.diff(live) >= 0).all()
