"""Expand-score kernel (beam-expansion scoring) + sort-based dedup tests.

The contract (DESIGN.md §10): the ``pallas`` scalar-prefetch kernel and the
``xla`` chunked twin run the identical elementwise network and must be
**bit-identical** (not merely allclose) for any shape and chunking — that
invariance is what makes mixed-semantics batches return exactly the
per-semantics answers.  ``legacy`` (the pre-fusion gather+matmul baseline)
is only allclose.  The traced-step memory profile certifies the quadratic
intermediates — the ``(B, C, d)`` candidate gather and the ``(·, C, C)``
dedup masks — exist only on the legacy path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.search import search_step_memory_profile
from repro.kernels import ops, ref
from repro.kernels.expand_score import (
    dedup_first,
    dedup_first_quadratic,
    expand_score_xla,
)

pytestmark = pytest.mark.hermetic  # runs in the no-hypothesis CI job


def make_case(seed, B, C, n, d):
    ks = jax.random.split(jax.random.key(seed), 3)
    x = jax.random.normal(ks[0], (n, d))
    q = jax.random.normal(ks[1], (B, d))
    idx = jax.random.randint(ks[2], (B, C), -1, n)
    return x, idx, q


@pytest.mark.parametrize("B,C,n,d", [(2, 4, 50, 8), (9, 16, 200, 32),
                                     (1, 64, 1000, 128), (7, 33, 123, 17),
                                     (3, 128, 400, 24)])
def test_backends_bitwise_and_oracle(B, C, n, d):
    x, idx, q = make_case(B * C, B, C, n, d)
    out_x = ops.expand_score(x, idx, q, backend="xla")
    out_p = ops.expand_score(x, idx, q, backend="pallas")
    out_l = ops.expand_score(x, idx, q, backend="legacy")
    # fused backends: bit-identical (elementwise per-row network)
    assert np.array_equal(np.asarray(out_x), np.asarray(out_p))
    # oracle (elementwise gather ref) and legacy (matmul identity): allclose
    expect = ref.gather_sq_dist(x, idx, q)
    finite = np.isfinite(np.asarray(expect))
    for out in (out_x, out_l):
        assert (np.isfinite(np.asarray(out)) == finite).all()
        np.testing.assert_allclose(
            np.where(finite, np.asarray(out), 0),
            np.where(finite, np.asarray(expect), 0), atol=1e-4,
        )


@pytest.mark.parametrize("chunk", [1, 7, 32, 200])
def test_xla_chunk_invariance(chunk):
    """Any chunking of the candidate axis is bitwise invisible — the claim
    the mixed-batch bit-identity contract rests on."""
    x, idx, q = make_case(11, 5, 37, 300, 19)
    base = expand_score_xla(x, idx, q, chunk=32)
    out = expand_score_xla(x, idx, q, chunk=chunk)
    assert np.array_equal(np.asarray(base), np.asarray(out))


def test_batch_composition_invariance():
    """Per-row results do not depend on which other rows share the batch."""
    x, idx, q = make_case(13, 8, 24, 150, 12)
    full = np.asarray(ops.expand_score(x, idx, q, backend="xla"))
    for rows in ([0], [3, 5], [7, 0, 2]):
        sel = np.asarray(rows)
        sub = np.asarray(ops.expand_score(x, idx[sel], q[sel], backend="xla"))
        assert np.array_equal(full[sel], sub)


def _dedup_oracle(ids, flag):
    """Literal first-eligible-occurrence semantics, per row in python."""
    out = np.zeros_like(flag)
    for b in range(ids.shape[0]):
        seen = set()
        for t in range(ids.shape[1]):
            if flag[b, t] and int(ids[b, t]) not in seen:
                out[b, t] = True
                seen.add(int(ids[b, t]))
    return out


@pytest.mark.slow
@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_dedup_sort_matches_quadratic(seed):
    """Sort-based dedup == the O(C²) pairwise mask == the python oracle,
    bit-for-bit, under heavy id collision."""
    rng = np.random.default_rng(seed)
    B = int(rng.integers(1, 6))
    C = int(rng.integers(1, 48))
    ids = rng.integers(0, max(C // 3, 2), (B, C)).astype(np.int32)
    flag = rng.uniform(size=(B, C)) < 0.6
    got = np.asarray(dedup_first(jnp.asarray(ids), jnp.asarray(flag)))
    quad = np.asarray(dedup_first_quadratic(jnp.asarray(ids), jnp.asarray(flag)))
    assert np.array_equal(got, quad)
    assert np.array_equal(got, _dedup_oracle(ids, flag))


def test_dedup_unflagged_slots_do_not_suppress():
    """An unflagged earlier duplicate must not shadow a later flagged one."""
    ids = jnp.asarray([[4, 4, 4]], jnp.int32)
    flag = jnp.asarray([[False, True, True]])
    out = np.asarray(dedup_first(ids, flag))
    assert out.tolist() == [[False, True, False]]


def test_step_profile_no_quadratic_on_new_path():
    """ISSUE-3 acceptance: one traced fused search step materializes neither
    the (B, C, d) candidate gather nor any (·, C, C) dedup tensor on the new
    backends; the legacy expand/dedup pair shows both."""
    # width=1 shrinks C to M, which must not collapse the xla twin into a
    # single full-width chunk (that would be the banned gather)
    for backend in ("xla", "pallas"):
        for width in (1, 4):
            prof = search_step_memory_profile(backend, width=width)
            assert not prof["gather_bcd"], (backend, width)
            assert not prof["quadratic_cc"], (backend, width)
    legacy = search_step_memory_profile("legacy")
    assert legacy["gather_bcd"] and legacy["quadratic_cc"]
    # and fusion actually shrinks the peak live intermediate
    assert search_step_memory_profile("xla")["peak_bytes"] < legacy["peak_bytes"]
