"""Distributed (row-sharded) unified index — the production serving path.

The corpus is sharded row-wise over the ``data`` (and ``pod``) mesh axes.
Structural heredity (Thm 3.5/4.1) is what makes shard-local graphs sound:
each shard's sub-index is a valid unified graph over its rows, so shard-local
beam search + a global top-k merge is a correct (and embarrassingly parallel)
decomposition of the query.

Collective schedule (see DESIGN.md §4 and EXPERIMENTS.md §Perf):

* baseline merge — one ``all_gather`` of per-shard top-k over every index
  axis, then a replicated sort;
* hierarchical merge — intra-pod ``all_gather`` + local reduce first, then
  the (slow, cross-pod) axis moves only ``k`` survivors per pod instead of
  ``k`` per chip: cross-pod bytes drop by the pod size (16×).

Also here: the ring-streamed exact KNN builder used to bootstrap candidate
sets when the corpus is too large for any single host (each shard's rows
visit every other shard once via ``ppermute`` — compute/comm overlapped by
construction since each ring step's matmul hides the next permute).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import intervals as iv
from repro.core.candidates import merge_topk
from repro.core.entry import build_entry_index, get_entry_batch_flags, get_entry_flags
from repro.core.search import beam_search_flags

from repro import compat
from repro.compat import shard_map


class ShardedIndexArrays(NamedTuple):
    """Device arrays of a row-sharded index (all sharded along axis 0 over the
    index axes, except queries which are replicated)."""

    x: jnp.ndarray          # (n, d) rows sharded
    intervals: jnp.ndarray  # (n, 2) rows sharded
    nbrs: jnp.ndarray       # (n, M) shard-LOCAL neighbor ids
    status: jnp.ndarray     # (n, M)
    global_ids: jnp.ndarray # (n,) shard-local row -> global id


def shard_index(
    mesh: Mesh,
    index_axes: Sequence[str],
    x: np.ndarray,
    intervals: np.ndarray,
    nbrs: np.ndarray,
    status: np.ndarray,
    global_ids: np.ndarray,
) -> ShardedIndexArrays:
    """Place host arrays onto the mesh, rows sharded over ``index_axes``."""
    row = P(tuple(index_axes))
    put = lambda a, spec: jax.device_put(a, NamedSharding(mesh, spec))
    return ShardedIndexArrays(
        put(x, row), put(intervals, row), put(nbrs, row),
        put(status, row), put(global_ids, row),
    )


def make_sharded_search_fn(
    mesh: Mesh,
    *,
    index_axes: Sequence[str] = ("data",),
    replicated_axes: Sequence[str] = ("model",),
    sem: iv.Semantics = iv.Semantics.IF,
    ef: int = 64,
    k: int = 10,
    hierarchical: bool = True,
    backend: str | None = None,
    width: int = 4,
    mixed: bool = False,
):
    """Build the jittable sharded search step.

    Inside ``shard_map`` every device runs Alg. 5 + Alg. 4 on its rows, then
    the per-shard top-k are merged across the index axes.  With
    ``hierarchical=True`` and 2 index axes (pod, data), the merge reduces
    intra-pod first so only ``k`` candidates per pod cross the pod axis.
    ``backend``/``width`` select the shard-local search pipeline (fused
    multi-expansion by default; see core/search.py).

    With ``mixed=True`` the returned function takes one extra trailing
    argument — a replicated ``(B,)`` int32 sem-flag array — and the single
    compiled program serves interleaved IF/IS/RF/RS traffic (the shard-local
    search is flag-driven either way; DESIGN.md §10).
    """
    index_axes = tuple(index_axes)

    def local_search(x, ints, nbrs, status, gids, q_v, q_int, sem_flags):
        # Rows with gids < 0 are pads OR shard-level tombstones (a streaming
        # delete flips the row's gid to -1): both are masked out of the
        # entry structure so they can never be certified by Alg. 5
        # (Lemma 4.3 soundness), and the same mask threads into the beam
        # search as the alive mask — tombstoned rows still route traffic
        # through their edges but never surface (DESIGN.md §11).
        alive = gids >= 0
        eidx = build_entry_index(ints, node_mask=alive)
        if backend == "legacy":
            entry = get_entry_flags(eidx, q_int, sem_flags)
        else:
            entry = get_entry_batch_flags(eidx, q_int, sem_flags, width=width)
        res = beam_search_flags(
            x, ints, nbrs, status, entry, q_v, q_int, sem_flags, alive,
            ef=ef, k=k, backend=backend, width=width,
        )
        nloc = x.shape[0]
        g = jnp.where(res.ids >= 0, gids[jnp.clip(res.ids, 0, nloc - 1)], -1)
        return g, res.dist

    def merge_axis(ids, dist, axis_name):
        """all_gather per-shard candidates along one axis and re-reduce."""
        ga = jax.lax.all_gather(ids, axis_name, axis=1)     # (B, S, k)
        gd = jax.lax.all_gather(dist, axis_name, axis=1)
        B = ga.shape[0]
        ga = ga.reshape(B, -1)
        gd = gd.reshape(B, -1)
        order = jnp.argsort(gd, axis=-1)[:, :k]
        return (
            jnp.take_along_axis(ga, order, axis=-1),
            jnp.take_along_axis(gd, order, axis=-1),
        )

    def sharded(x, ints, nbrs, status, gids, q_v, q_int, sem_flags):
        ids, dist = local_search(x, ints, nbrs, status, gids, q_v, q_int, sem_flags)
        if hierarchical:
            # innermost (fast, intra-pod) axis first, then outer axes.
            for ax in reversed(index_axes):
                ids, dist = merge_axis(ids, dist, ax)
        else:
            ids, dist = merge_axis(
                ids, dist, index_axes if len(index_axes) > 1 else index_axes[0]
            )
        return ids, dist

    row = P(tuple(index_axes))
    rep = P()
    if mixed:
        body, in_specs = sharded, (row,) * 5 + (rep, rep, rep)
    else:
        # Static-semantics signature (7 args): flags broadcast from ``sem``.
        def body(x, ints, nbrs, status, gids, q_v, q_int):
            flags = jnp.full(q_v.shape[:1], sem.flag, jnp.int32)
            return sharded(x, ints, nbrs, status, gids, q_v, q_int, flags)

        in_specs = (row,) * 5 + (rep, rep)
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(rep, rep),
        check_vma=False,
    )
    return jax.jit(fn)


# --------------------------------------------------------------------------
# Ring-streamed exact KNN (distributed candidate bootstrap)
# --------------------------------------------------------------------------
def make_ring_knn_fn(mesh: Mesh, *, axis: str = "data", k: int = 32):
    """Exact KNN graph over a row-sharded corpus via a ``ppermute`` ring.

    Each step, every shard scores its rows against the visiting column block
    and folds the result into its running top-k; the block then moves one hop
    around the ring.  After ``n_shards`` steps every pair has been scored.
    This is the sharded replacement for NN-descent bootstrap on corpora that
    exceed a single host (DESIGN.md §4).
    """

    def ring_knn(x, gids):
        nloc = x.shape[0]
        size = compat.axis_size(axis)
        me = jax.lax.axis_index(axis)
        perm = [(i, (i + 1) % size) for i in range(size)]

        def step(carry, _):
            blk_x, blk_ids, best_i, best_d = carry
            d = jnp.sum(
                (x[:, None, :].astype(jnp.float32) - blk_x[None, :, :].astype(jnp.float32)) ** 2,
                axis=-1,
            )
            d = jnp.where(blk_ids[None, :] == gids[:, None], jnp.inf, d)  # self
            take = min(k, blk_x.shape[0])
            neg, idx = jax.lax.top_k(-d, take)
            cand_ids = jnp.take_along_axis(
                jnp.broadcast_to(blk_ids[None, :], d.shape), idx, axis=-1
            )
            best_i, best_d = merge_topk(best_i, best_d, cand_ids, -neg, k)
            blk_x = jax.lax.ppermute(blk_x, axis, perm)
            blk_ids = jax.lax.ppermute(blk_ids, axis, perm)
            return (blk_x, blk_ids, best_i, best_d), None

        init = (
            x,
            gids,
            jnp.full((nloc, k), -1, jnp.int32),
            jnp.full((nloc, k), jnp.inf, jnp.float32),
        )
        (_, _, best_i, best_d), _ = jax.lax.scan(step, init, None, length=size)
        return best_i, best_d

    row = P((axis,))
    fn = shard_map(
        ring_knn, mesh=mesh, in_specs=(row, row), out_specs=(row, row),
        check_vma=False,
    )
    return jax.jit(fn)


def build_sharded_index_host(
    x: np.ndarray,
    intervals: np.ndarray,
    n_shards: int,
    cfg,
    seed: int = 0,
):
    """Host-side helper: partition rows round-robin and build one UG per
    shard (heredity ⇒ per-shard graphs are sound).  Returns per-shard arrays
    padded to a common width, ready for :func:`shard_index`."""
    from repro.core.build import build_ug

    n = x.shape[0]
    per = (n + n_shards - 1) // n_shards
    xs, its, nbs, sts, gid = [], [], [], [], []
    max_m = 1
    shards = []
    for s in range(n_shards):
        rows = np.arange(s, n, n_shards)[:per]
        g = build_ug(
            jax.random.key(seed + s), jnp.asarray(x[rows]), jnp.asarray(intervals[rows]), cfg
        )
        shards.append((rows, g))
        max_m = max(max_m, g.nbrs.shape[1])
    for rows, g in shards:
        m = g.nbrs.shape[1]
        nb = np.full((per, max_m), -1, np.int32)
        st = np.zeros((per, max_m), np.uint8)
        nloc = rows.shape[0]
        nb[:nloc, :m] = np.asarray(g.nbrs)
        st[:nloc, :m] = np.asarray(g.status)
        xpad = np.zeros((per, x.shape[1]), x.dtype)
        xpad[:nloc] = x[rows]
        ipad = np.zeros((per, 2), intervals.dtype)
        # Padded rows get inverted intervals so no predicate ever matches.
        ipad[:, 0], ipad[:, 1] = 2.0, -2.0
        ipad[:nloc] = intervals[rows]
        gpad = np.full((per,), -1, np.int32)
        gpad[:nloc] = rows
        xs.append(xpad); its.append(ipad); nbs.append(nb); sts.append(st); gid.append(gpad)
    cat = lambda arrs: np.concatenate(arrs, axis=0)
    return cat(xs), cat(its), cat(nbs), cat(sts), cat(gid)
