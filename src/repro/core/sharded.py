"""Distributed (row-sharded) unified index — the production serving path.

The corpus is sharded row-wise over the ``data`` (and ``pod``) mesh axes.
Structural heredity (Thm 3.5/4.1) is what makes shard-local graphs sound:
each shard's sub-index is a valid unified graph over its rows, so shard-local
beam search + a global top-k merge is a correct (and embarrassingly parallel)
decomposition of the query.

Since DESIGN.md §12 the sharded index is the *same* :class:`IndexStore`
pytree the single-host path serves — leaves row-sharded over the index
axes, quantization parameters replicated — wrapped with the shard-local →
global id map in :class:`ShardedIndex`.  There is no separate sharded
representation anymore.

Collective schedule (see DESIGN.md §4 and EXPERIMENTS.md §Perf):

* baseline merge — one ``all_gather`` of per-shard top-k over every index
  axis, then a replicated sort;
* hierarchical merge — intra-pod ``all_gather`` + local reduce first, then
  the (slow, cross-pod) axis moves only ``k`` survivors per pod instead of
  ``k`` per chip: cross-pod bytes drop by the pod size (16×).

Construction (DESIGN.md §12): :func:`build_sharded_store` builds every
shard's graph **on device** in one jitted ``shard_map`` program — the
ring-KNN bootstrap (``ppermute`` pipeline, masked to own-shard rows)
replaces per-shard NN-descent, shard-local attribute sort orders supply
the Alg. 1 interval candidates, and the same jitted ``_prune_all`` /
repair iterations the single-host build runs (``build.refine_candidates``)
refine each shard — no per-shard host ``build_ug`` calls, no round-robin
numpy padding loop.  :func:`build_sharded_index_host` remains as the
serial host reference the parity tests compare against.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import intervals as iv
from repro.core.build import refine_candidates
from repro.core.candidates import attribute_candidates, merge_topk
from repro.core.entry import build_entry_index, get_entry_batch_flags, get_entry_flags
from repro.core.prune import squared_dist
from repro.core.search import beam_search_flags
from repro.core.store import (
    IndexStore, VectorPlane, quantization_params, train_pq_codebooks,
)

from repro import compat
from repro.compat import shard_map


class ShardedIndex(NamedTuple):
    """A row-sharded :class:`IndexStore` + the shard-local → global id map.

    ``store`` carries ``entry=None`` (each shard builds its entry structure
    over its own rows inside ``shard_map``) and ``alive=None`` (liveness is
    ``global_ids >= 0`` — a pad or shard-level tombstone flips the gid).
    """

    store: IndexStore
    global_ids: jnp.ndarray  # (n,) shard-local row -> global id, -1 = pad


def _plane_like(plane, row, rep):
    """A VectorPlane-shaped pytree with per-leaf values (specs/shardings)."""
    if plane is None:
        return None
    return VectorPlane(
        plane.tag, row,
        None if plane.scale is None else rep,
        None if plane.zero is None else rep,
        None if plane.codebooks is None else rep,
    )


def store_pspecs(store: IndexStore, index_axes: Sequence[str]):
    """PartitionSpec pytree of a row-sharded store: capacity-leading arrays
    over ``index_axes``, quantization parameters (int8 scale/zero, pq
    codebooks) replicated."""
    row = P(tuple(index_axes))
    rep = P()
    none_or_row = lambda a: None if a is None else row
    return IndexStore(
        plane=_plane_like(store.plane, row, rep),
        rerank=_plane_like(store.rerank, row, rep),
        intervals=row, nbrs=row, status=row,
        entry=None if store.entry is None else jax.tree.map(
            lambda _: row, store.entry),
        alive=none_or_row(store.alive), free=none_or_row(store.free),
    )


def shard_index(
    mesh: Mesh,
    index_axes: Sequence[str],
    x: np.ndarray,
    intervals: np.ndarray,
    nbrs: np.ndarray,
    status: np.ndarray,
    global_ids: np.ndarray,
    *,
    dtype: str = "f32",
    rerank: bool = False,
    qparams=None,
) -> ShardedIndex:
    """Assemble host arrays into a row-sharded :class:`ShardedIndex`.

    ``dtype``/``rerank`` encode the vector planes exactly as the single-host
    store does (core/store.py); quantization parameters are derived over
    the *real* rows only (``global_ids >= 0`` — the host builder's zero
    pad rows would otherwise widen the per-dim ranges and inflate the
    quantization error), or passed via ``qparams``, and replicated.
    """
    x = jnp.asarray(x)
    if dtype in ("int8", "pq") and qparams is None:
        real = np.asarray(global_ids) >= 0
        xr = x[jnp.asarray(real)]
        qparams = (
            quantization_params(xr) if dtype == "int8"
            else train_pq_codebooks(xr)
        )
    store = IndexStore(
        plane=VectorPlane.encode(x, dtype, qparams),
        rerank=VectorPlane.encode(x, "f32") if rerank else None,
        intervals=jnp.asarray(intervals),
        nbrs=jnp.asarray(nbrs),
        status=jnp.asarray(status),
        entry=None,
    )
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), store_pspecs(store, index_axes),
        is_leaf=lambda v: isinstance(v, P),
    )
    row = NamedSharding(mesh, P(tuple(index_axes)))
    return ShardedIndex(
        jax.device_put(store, shardings),
        jax.device_put(jnp.asarray(global_ids), row),
    )


def make_sharded_search_fn(
    mesh: Mesh,
    *,
    index_axes: Sequence[str] = ("data",),
    replicated_axes: Sequence[str] = ("model",),
    sem: iv.Semantics = iv.Semantics.IF,
    ef: int = 64,
    k: int = 10,
    hierarchical: bool = True,
    backend: str | None = None,
    width: int = 4,
    mixed: bool = False,
    plane_tag: str = "f32",
    has_rerank: bool = False,
):
    """Build the jittable sharded search step over a :class:`ShardedIndex`.

    Inside ``shard_map`` every device runs Alg. 5 + Alg. 4 on its rows —
    through the *same* store-based ``beam_search_flags`` the single-host
    path serves, so plane dispatch (f32/bf16/int8 + rerank) carries over
    unchanged — then the per-shard top-k are merged across the index axes.
    With ``hierarchical=True`` and 2 index axes (pod, data), the merge
    reduces intra-pod first so only ``k`` candidates per pod cross the pod
    axis.  ``backend``/``width`` select the shard-local search pipeline.

    With ``mixed=True`` the returned function takes one extra trailing
    argument — a replicated ``(B,)`` int32 sem-flag array — and the single
    compiled program serves interleaved IF/IS/RF/RS traffic (DESIGN.md §10).

    ``plane_tag``/``has_rerank`` declare the store layout the returned
    function will be called with (they fix the in_specs pytree; the actual
    kernel dispatch happens on the store's own tag).
    """
    index_axes = tuple(index_axes)

    def local_search(store: IndexStore, gids, q_v, q_int, sem_flags):
        # Rows with gids < 0 are pads OR shard-level tombstones (a streaming
        # delete flips the row's gid to -1): both are masked out of the
        # entry structure so they can never be certified by Alg. 5
        # (Lemma 4.3 soundness), and the same mask becomes the store's
        # alive mask — tombstoned rows still route traffic through their
        # edges but never surface (DESIGN.md §11).
        alive = gids >= 0
        eidx = build_entry_index(store.intervals, node_mask=alive)
        st = store.replace(entry=eidx, alive=alive)
        if backend == "legacy":
            entry = get_entry_flags(eidx, q_int, sem_flags)
        else:
            entry = get_entry_batch_flags(eidx, q_int, sem_flags, width=width)
        res = beam_search_flags(
            st, entry, q_v, q_int, sem_flags,
            ef=ef, k=k, backend=backend, width=width,
        )
        nloc = store.capacity
        g = jnp.where(res.ids >= 0, gids[jnp.clip(res.ids, 0, nloc - 1)], -1)
        return g, res.dist

    def merge_axis(ids, dist, axis_name):
        """all_gather per-shard candidates along one axis and re-reduce."""
        ga = jax.lax.all_gather(ids, axis_name, axis=1)     # (B, S, k)
        gd = jax.lax.all_gather(dist, axis_name, axis=1)
        B = ga.shape[0]
        ga = ga.reshape(B, -1)
        gd = gd.reshape(B, -1)
        order = jnp.argsort(gd, axis=-1)[:, :k]
        return (
            jnp.take_along_axis(ga, order, axis=-1),
            jnp.take_along_axis(gd, order, axis=-1),
        )

    def sharded(store, gids, q_v, q_int, sem_flags):
        ids, dist = local_search(store, gids, q_v, q_int, sem_flags)
        if hierarchical:
            # innermost (fast, intra-pod) axis first, then outer axes.
            for ax in reversed(index_axes):
                ids, dist = merge_axis(ids, dist, ax)
        else:
            ids, dist = merge_axis(
                ids, dist, index_axes if len(index_axes) > 1 else index_axes[0]
            )
        return ids, dist

    row = P(index_axes)
    rep = P()
    # The in_specs pytree mirrors the ShardedIndex layout the caller holds.
    template = IndexStore(
        plane=VectorPlane(plane_tag, None,
                          None if plane_tag != "int8" else True,
                          None if plane_tag != "int8" else True,
                          None if plane_tag != "pq" else True),
        rerank=None if not has_rerank else VectorPlane("f32", None),
        intervals=None, nbrs=None, status=None, entry=None,
    )
    store_specs = store_pspecs(template, index_axes)
    if mixed:
        def body(sidx, q_v, q_int, sem_flags):
            return sharded(sidx.store, sidx.global_ids, q_v, q_int, sem_flags)

        in_specs = (ShardedIndex(store_specs, row), rep, rep, rep)
    else:
        # Static-semantics signature: flags broadcast from ``sem``.
        def body(sidx, q_v, q_int):
            flags = jnp.full(q_v.shape[:1], sem.flag, jnp.int32)
            return sharded(sidx.store, sidx.global_ids, q_v, q_int, flags)

        in_specs = (ShardedIndex(store_specs, row), rep, rep)
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(rep, rep),
        check_vma=False,
    )
    return jax.jit(fn)


def local_shard_view(sidx: ShardedIndex, s: int, n_shards: int):
    """Shard ``s``'s row block of a :class:`ShardedIndex` as a standalone
    ``(IndexStore, global_ids)`` pair.

    Row-sharded leaves partition axis 0 into equal contiguous blocks per
    shard (that is what ``P((index_axes,))`` means), so shard ``s`` is rows
    ``[s·per, (s+1)·per)``; replicated leaves (quantization parameters) are
    shared.  The view is the unit of the straggler probe
    (:func:`make_shard_probe_fns`): searching it alone reproduces exactly
    what shard ``s`` computes inside the ``shard_map`` program.
    """
    cap = sidx.store.capacity
    if cap % n_shards:
        raise ValueError(f"capacity {cap} not divisible by {n_shards} shards")
    per = cap // n_shards
    sl = slice(s * per, (s + 1) * per)
    st = sidx.store

    def cut(pl):
        if pl is None:
            return None
        # rows are sliced; quantization params (scale/zero/codebooks) are
        # replicated across shards, so they pass through shared.
        return dataclasses.replace(pl, data=pl.data[sl])

    store = IndexStore(
        plane=cut(st.plane), rerank=cut(st.rerank),
        intervals=st.intervals[sl], nbrs=st.nbrs[sl], status=st.status[sl],
        entry=None,
    )
    return store, sidx.global_ids[sl]


def make_shard_probe_fns(
    sidx: ShardedIndex,
    n_shards: int,
    *,
    ef: int = 64,
    k: int = 10,
    backend: str | None = None,
    width: int = 4,
):
    """Per-shard local-search callables for straggler probing (DESIGN.md §13).

    Shard ``s``'s callable runs the *same* shard-local program the sharded
    search step runs inside ``shard_map`` — entry structure over own rows,
    ``beam_search_flags``, gid mapping — but on shard ``s``'s row block
    alone, so timing one call isolates that shard's step cost.  The serve
    runtime's :class:`~repro.serve.runtime.FleetServeMonitor` feeds these
    timings into :class:`~repro.ft.straggler.FleetMonitor` to turn slow
    shards into mitigation recommendations and
    :func:`~repro.ft.elastic.plan_serve_rescale` replica plans.

    All shards share one compiled program (the row blocks are equal-shaped;
    the shard's arrays are call arguments, not closure constants).  Returns
    a list of ``fn(q_v, q_int, sem_flags) -> (global_ids, dist)``.
    """
    views = [local_shard_view(sidx, s, n_shards) for s in range(n_shards)]

    @jax.jit
    def probe(store, gids, q_v, q_int, sem_flags):
        alive = gids >= 0
        eidx = build_entry_index(store.intervals, node_mask=alive)
        st = store.replace(entry=eidx, alive=alive)
        if backend == "legacy":
            entry = get_entry_flags(eidx, q_int, sem_flags)
        else:
            entry = get_entry_batch_flags(eidx, q_int, sem_flags, width=width)
        res = beam_search_flags(
            st, entry, q_v, q_int, sem_flags,
            ef=ef, k=k, backend=backend, width=width,
        )
        nloc = store.capacity
        g = jnp.where(res.ids >= 0, gids[jnp.clip(res.ids, 0, nloc - 1)], -1)
        return g, res.dist

    def bind(store, gids):
        return lambda q_v, q_int, sem_flags: probe(
            store, gids, q_v, q_int, sem_flags)

    return [bind(store, gids) for store, gids in views]


# --------------------------------------------------------------------------
# Ring-streamed exact KNN (distributed candidate bootstrap)
# --------------------------------------------------------------------------
def _ring_knn_step_fn(axis: str, k: int, *, same_shard_of: int | None = None):
    """Shared body of the ring passes: every step scores the local rows
    against the visiting column block and folds the result into the running
    top-k; the block then moves one hop around the ring.

    ``same_shard_of=None`` keeps every candidate (global exact KNN);
    ``same_shard_of=S`` keeps only candidates of the caller's own shard
    under the round-robin layout (``gid % S == me``) and returns their
    *shard-local* ids (``gid // S``) — the bootstrap of the on-device
    sharded build, where the per-shard graph may only reference own rows.
    """

    def ring(x, gids):
        nloc = x.shape[0]
        size = compat.axis_size(axis)
        me = jax.lax.axis_index(axis)
        perm = [(i, (i + 1) % size) for i in range(size)]

        def step(carry, _):
            blk_x, blk_ids, best_i, best_d = carry
            d = squared_dist(x, blk_x)                       # (nloc, blk)
            keep = (blk_ids[None, :] != gids[:, None]) & (blk_ids >= 0)[None, :]
            if same_shard_of is not None:
                keep = keep & ((blk_ids % same_shard_of) == me)[None, :]
                cand_pool = blk_ids // same_shard_of         # shard-local ids
            else:
                cand_pool = blk_ids
            d = jnp.where(keep, d, jnp.inf)
            take = min(k, blk_x.shape[0])
            neg, idx = jax.lax.top_k(-d, take)
            cand_ids = jnp.take_along_axis(
                jnp.broadcast_to(cand_pool[None, :], d.shape), idx, axis=-1
            )
            cand_ids = jnp.where(jnp.isfinite(neg), cand_ids, -1)
            best_i, best_d = merge_topk(best_i, best_d, cand_ids, -neg, k)
            blk_x = jax.lax.ppermute(blk_x, axis, perm)
            blk_ids = jax.lax.ppermute(blk_ids, axis, perm)
            return (blk_x, blk_ids, best_i, best_d), None

        init = (
            x,
            gids,
            jnp.full((nloc, k), -1, jnp.int32),
            jnp.full((nloc, k), jnp.inf, jnp.float32),
        )
        (_, _, best_i, best_d), _ = jax.lax.scan(step, init, None, length=size)
        return best_i, best_d

    return ring


def make_ring_knn_fn(mesh: Mesh, *, axis: str = "data", k: int = 32):
    """Exact KNN graph over a row-sharded corpus via a ``ppermute`` ring.

    Each step, every shard scores its rows against the visiting column block
    and folds the result into its running top-k; the block then moves one hop
    around the ring.  After ``n_shards`` steps every pair has been scored.
    This is the sharded replacement for NN-descent bootstrap on corpora that
    exceed a single host (DESIGN.md §4); the same ring (own-shard-masked)
    bootstraps the on-device sharded build.
    """
    row = P((axis,))
    fn = shard_map(
        _ring_knn_step_fn(axis, k), mesh=mesh, in_specs=(row, row),
        out_specs=(row, row), check_vma=False,
    )
    return jax.jit(fn)


# --------------------------------------------------------------------------
# Construction
# --------------------------------------------------------------------------
def _round_robin_layout(n: int, S: int):
    """Round-robin partition: shard ``s`` slot ``j`` ↔ global id ``s + j·S``
    (identical to the host reference path).  Returns the flat (S·per,) gid
    array with ``-1`` pads; at most one pad row per shard."""
    per = (n + S - 1) // S
    gid = (np.arange(S)[:, None] + np.arange(per)[None, :] * S).reshape(-1)
    return np.where(gid < n, gid, -1).astype(np.int32), per


def build_sharded_store(
    mesh: Mesh,
    x: np.ndarray,
    intervals: np.ndarray,
    cfg,
    *,
    index_axes: Sequence[str] = ("data",),
    dtype: str = "f32",
    rerank: bool = False,
    backend: str | None = None,
) -> ShardedIndex:
    """On-device sharded build (DESIGN.md §12): one jitted ``shard_map``
    program constructs every shard's unified graph in parallel.

    Per shard: the ring-KNN bootstrap (own-shard-masked exact KNN through
    the ``ppermute`` pipeline — no shard ever holds more than one visiting
    block) supplies the spatial candidates, shard-local attribute sort
    orders the Alg. 1 interval candidates, and ``build.refine_candidates``
    — the *same* jitted ``_prune_all`` + repair-scatter iterations the
    single-host build runs — refines them into the final graph.  No
    per-shard host ``build_ug`` calls, no round-robin numpy padding loop:
    the only host work is the O(n) round-robin permutation and a single
    device→host sync for the trailing-column trim.

    Rows partition round-robin exactly like the host reference
    (:func:`build_sharded_index_host`), so the two paths build statistically
    identical shards (the parity test pins sharded-search recall within
    0.01 across all four semantics).
    """
    if len(index_axes) != 1:
        raise NotImplementedError(
            "on-device sharded build rings over one index axis; flatten "
            "multi-axis meshes into the data axis for construction")
    axis = index_axes[0]
    S = mesh.shape[axis]
    x = np.asarray(x)
    intervals = np.asarray(intervals)
    n, d = x.shape
    gids, per = _round_robin_layout(n, S)
    n_pad = per * S

    safe = np.clip(gids, 0, n - 1)
    xs = np.where((gids >= 0)[:, None], x[safe], 0.0).astype(np.float32)
    its = np.where(
        (gids >= 0)[:, None], intervals[safe],
        np.asarray([2.0, -2.0], intervals.dtype),  # pads: no predicate matches
    )

    row = NamedSharding(mesh, P((axis,)))
    xs_d = jax.device_put(jnp.asarray(xs), row)
    its_d = jax.device_put(jnp.asarray(its), row)
    gids_d = jax.device_put(jnp.asarray(gids), row)

    ring = _ring_knn_step_fn(axis, int(cfg.ef_spatial), same_shard_of=S)

    def shard_build(xloc, ivloc, gidloc):
        valid = gidloc >= 0
        nloc = xloc.shape[0]
        # (1) spatial candidates: ring-KNN bootstrap masked to own shard.
        spa, _ = ring(xloc, gidloc)
        # (2) attribute candidates: shard-local Alg. 1 sort orders.
        attr = attribute_candidates(ivloc, cfg.ef_attribute)
        cand = jnp.concatenate([spa, attr], axis=1)
        self_ids = jnp.arange(nloc, dtype=jnp.int32)[:, None]
        cand = jnp.where(cand == self_ids, -1, cand)
        cand_c = jnp.clip(cand, 0, nloc - 1)
        cand = jnp.where((cand >= 0) & valid[cand_c], cand, -1)
        # (3) the jitted prune/repair iterations (same program as build_ug).
        nbrs, stat, _ = refine_candidates(xloc, ivloc, cand, cfg, backend)
        nbrs = jnp.where(valid[:, None] & (nbrs >= 0), nbrs, -1)
        stat = jnp.where(nbrs >= 0, stat, 0).astype(jnp.uint8)
        return nbrs, stat

    rowp = P((axis,))
    build_fn = jax.jit(shard_map(
        shard_build, mesh=mesh, in_specs=(rowp, rowp, rowp),
        out_specs=(rowp, rowp), check_vma=False,
    ))
    nbrs, stat = build_fn(xs_d, its_d, gids_d)

    # Single device→host sync: global trailing-column trim across shards.
    live_cols = max(int(jnp.max(jnp.sum(nbrs >= 0, axis=1))), 1)
    nbrs = jax.device_put(nbrs[:, :live_cols], row)
    stat = jax.device_put(stat[:, :live_cols], row)

    # Quantization params derive from the real rows (x, not the padded xs —
    # zero pads would widen the int8 ranges / skew the pq centroids).
    qparams = None
    if dtype == "int8":
        qparams = quantization_params(jnp.asarray(x))
    elif dtype == "pq":
        qparams = train_pq_codebooks(jnp.asarray(x))
    store = IndexStore(
        plane=VectorPlane.encode(jnp.asarray(xs), dtype, qparams),
        rerank=VectorPlane.encode(jnp.asarray(xs), "f32") if rerank else None,
        intervals=its_d, nbrs=nbrs, status=stat, entry=None,
    )
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), store_pspecs(store, index_axes),
        is_leaf=lambda v: isinstance(v, P),
    )
    return ShardedIndex(jax.device_put(store, shardings), gids_d)


def build_sharded_index_host(
    x: np.ndarray,
    intervals: np.ndarray,
    n_shards: int,
    cfg,
    seed: int = 0,
):
    """Host-side reference: partition rows round-robin and build one UG per
    shard with the serial single-host builder (heredity ⇒ per-shard graphs
    are sound).  Returns per-shard arrays padded to a common width, ready
    for :func:`shard_index`.  Kept as the parity yardstick for
    :func:`build_sharded_store` (which replaces it on the hot path)."""
    from repro.core.build import build_ug

    n = x.shape[0]
    per = (n + n_shards - 1) // n_shards
    xs, its, nbs, sts, gid = [], [], [], [], []
    max_m = 1
    shards = []
    for s in range(n_shards):
        rows = np.arange(s, n, n_shards)[:per]
        g = build_ug(
            jax.random.key(seed + s), jnp.asarray(x[rows]), jnp.asarray(intervals[rows]), cfg
        )
        shards.append((rows, g))
        max_m = max(max_m, g.nbrs.shape[1])
    for rows, g in shards:
        m = g.nbrs.shape[1]
        nb = np.full((per, max_m), -1, np.int32)
        st = np.zeros((per, max_m), np.uint8)
        nloc = rows.shape[0]
        nb[:nloc, :m] = np.asarray(g.nbrs)
        st[:nloc, :m] = np.asarray(g.status)
        xpad = np.zeros((per, x.shape[1]), x.dtype)
        xpad[:nloc] = x[rows]
        ipad = np.zeros((per, 2), intervals.dtype)
        # Padded rows get inverted intervals so no predicate ever matches.
        ipad[:, 0], ipad[:, 1] = 2.0, -2.0
        ipad[:nloc] = intervals[rows]
        gpad = np.full((per,), -1, np.int32)
        gpad[:nloc] = rows
        xs.append(xpad); its.append(ipad); nbs.append(nb); sts.append(st); gid.append(gpad)
    cat = lambda arrs: np.concatenate(arrs, axis=0)
    return cat(xs), cat(its), cat(nbs), cat(sts), cat(gid)
