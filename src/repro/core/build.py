"""Iterative UG construction (paper Alg. 2) with repair sets.

Each iteration refines the candidate pool of every node by merging the
previously retained neighbors with the repair candidates produced when edges
were pruned (the pruned endpoint ``v`` is offered to its witness ``w`` so the
monotone continuation path through ``w`` can be explored next round).

TPU reformulation: repair sets are fixed-width per-node buffers filled by a
sort-by-witness + segment-rank scatter — no dynamic allocation; the pool
merge is padded-concat + dedup handled inside ``unified_prune``.

The full per-iteration sweep — blocked pruning over all ``n`` nodes plus the
repair scatter — is one jitted program: the node axis is padded to a
multiple of ``cfg.block`` and swept with ``lax.map`` (DESIGN.md §9), so the
host never re-enters the dispatch path per block and the only device→host
syncs in :func:`build_ug` are a single transfer at the end (degree stats for
``progress`` + the trailing-column trim).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.candidates import generate_candidates
from repro.core.exact import DenseGraph
from repro.core.prune import unified_prune
from repro.kernels.util import pad_rows, pad_to, segment_scatter


@dataclasses.dataclass(frozen=True)
class UGConfig:
    """Build hyper-parameters; defaults follow the paper's §5.1 (scaled names).

    Paper defaults: ef_spatial=128, ef_attribute=300, max_edges_IF =
    max_edges_IS = 256, 5 refinement iterations.
    """

    ef_spatial: int = 128
    ef_attribute: int = 300
    max_edges_if: int = 256
    max_edges_is: int = 256
    iterations: int = 5
    repair_width: int = 32          # W_max: bounded repair set per node
    alpha: float = 1.0              # RNG slack (1.0 = paper-faithful)
    unified: bool = True            # False = classical interval-agnostic RNG
    nnd_iters: int = 6
    exact_spatial: bool = False     # exact KNN candidates (small n oracle)
    block: int = 1024               # nodes pruned per jitted block
    prune_backend: str | None = None  # pallas | xla | legacy (None = platform)


def scatter_repairs(
    w_ids: jnp.ndarray, v_ids: jnp.ndarray, n: int, width: int
) -> jnp.ndarray:
    """Build fixed-width repair sets W(w) from flat (w, v) pairs (Alg. 2
    l.11-12) — the shared sort-by-segment + rank scatter
    (:func:`repro.kernels.util.segment_scatter`), kept under its Alg. 2
    name at the build layer."""
    return segment_scatter(w_ids, v_ids, n, width)


@functools.partial(jax.jit, static_argnames=("cfg", "keep", "backend"))
def _prune_all(
    x: jnp.ndarray,
    intervals: jnp.ndarray,
    cand: jnp.ndarray,
    cfg: UGConfig,
    keep: int,
    backend: str | None,
):
    """One full pruning sweep (Alg. 2 lines 8-9) over all nodes.

    A single jitted ``lax.map`` over ``cfg.block``-row tiles: no host block
    loop, no per-block dispatch.  Returns compacted neighbors/status plus the
    flat repair pairs (w, v) for :func:`scatter_repairs`.
    """
    n, C = cand.shape
    n_pad = pad_to(n, cfg.block)
    ids = jnp.arange(n_pad, dtype=jnp.int32)
    u_pad = jnp.where(ids < n, ids, 0)           # pad rows prune an empty pool
    cand_pad = pad_rows(cand, n_pad, -1)
    u_blocks = u_pad.reshape(-1, cfg.block)
    cand_blocks = cand_pad.reshape(-1, cfg.block, C)

    def one_block(args):
        u, cb = args
        res = unified_prune(
            u, cb, x, intervals,
            m_if=cfg.max_edges_if, m_is=cfg.max_edges_is,
            alpha=cfg.alpha, unified=cfg.unified, backend=backend,
        )
        # Compact retained neighbors to the front (ascending distance).
        score = jnp.where(res.status > 0, res.dist, jnp.inf)
        order = jnp.argsort(score, axis=-1)[:, :keep]
        ids_k = jnp.take_along_axis(res.order, order, axis=-1)
        st_k = jnp.take_along_axis(res.status, order, axis=-1)
        live = jnp.isfinite(jnp.take_along_axis(score, order, axis=-1))
        nbrs = jnp.where(live, ids_k, -1)
        stat = jnp.where(live, st_k, 0)
        # Repair pairs (w, v): witness gets the pruned endpoint.
        w_w = jnp.concatenate(
            [res.repair_if.reshape(-1), res.repair_is.reshape(-1)]
        )
        w_v = jnp.concatenate([
            jnp.where(res.repair_if >= 0, res.order, -1).reshape(-1),
            jnp.where(res.repair_is >= 0, res.order, -1).reshape(-1),
        ])
        return nbrs, stat, w_w, w_v

    nbrs, stat, w_w, w_v = jax.lax.map(one_block, (u_blocks, cand_blocks))
    return (
        nbrs.reshape(n_pad, keep)[:n],
        stat.reshape(n_pad, keep)[:n],
        w_w.reshape(-1),
        w_v.reshape(-1),
    )


def refine_candidates(
    x: jnp.ndarray,
    intervals: jnp.ndarray,
    cand: jnp.ndarray,
    cfg: UGConfig,
    backend: str | None = None,
):
    """The T-iteration Alg. 2 refinement over a prepared candidate pool:
    fused pruning sweep + repair-set scatter per round.

    Fully traceable (no host syncs, fixed ``keep`` width) — shared by
    :func:`build_ug` and the on-device sharded build, which runs this exact
    loop per shard under ``shard_map`` (core/sharded.py).  Returns
    ``(nbrs, stat, deg_means)`` at full ``keep`` width (untrimmed).
    """
    n = x.shape[0]
    repair = jnp.full((n, cfg.repair_width), -1, jnp.int32)
    nbrs = stat = None
    deg_means = []
    for t in range(cfg.iterations):
        pool = cand if t == 0 else jnp.concatenate([cand, repair], axis=1)
        keep = min(cfg.max_edges_if + cfg.max_edges_is, pool.shape[1])
        nbrs, stat, w_w, w_v = _prune_all(
            x, intervals, pool, cfg, keep, backend
        )
        cand = nbrs  # retained neighbors seed the next round (Alg. 2 line 10)
        repair = scatter_repairs(w_w, w_v, n, cfg.repair_width)
        deg_means.append(jnp.mean(jnp.sum(nbrs >= 0, axis=1).astype(jnp.float32)))
    return nbrs, stat, jnp.stack(deg_means)


def build_ug(
    key: jax.Array,
    x: jnp.ndarray,
    intervals: jnp.ndarray,
    cfg: UGConfig = UGConfig(),
    progress: Callable[[str], None] | None = None,
) -> DenseGraph:
    """Paper Alg. 1 + Alg. 2: candidate generation then T pruning iterations.

    All iterations run on-device; degree statistics accumulate as device
    scalars and transfer to the host in a single sync after the last sweep
    (together with the trailing-column trim bound).
    """
    cand = generate_candidates(
        key, x, intervals,
        ef_spatial=cfg.ef_spatial, ef_attribute=cfg.ef_attribute,
        nnd_iters=cfg.nnd_iters, exact_spatial=cfg.exact_spatial,
    )
    if progress is not None:
        progress(f"candidates: shape {cand.shape}")

    nbrs, stat, deg_means = refine_candidates(
        x, intervals, cand, cfg, cfg.prune_backend
    )

    # Single device→host sync: per-iteration degree stats + trailing trim.
    live_cols = jnp.maximum(jnp.max(jnp.sum(nbrs >= 0, axis=1)), 1)
    live_cols, deg_host = jax.device_get((live_cols, deg_means))
    if progress is not None:
        for t, dm in enumerate(np.asarray(deg_host)):
            progress(f"iter {t + 1}/{cfg.iterations}: mean degree {float(dm):.1f}")

    return DenseGraph(nbrs[:, : int(live_cols)], stat[:, : int(live_cols)])
