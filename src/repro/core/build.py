"""Iterative UG construction (paper Alg. 2) with repair sets.

Each iteration refines the candidate pool of every node by merging the
previously retained neighbors with the repair candidates produced when edges
were pruned (the pruned endpoint ``v`` is offered to its witness ``w`` so the
monotone continuation path through ``w`` can be explored next round).

TPU reformulation: repair sets are fixed-width per-node buffers filled by a
sort-by-witness + segment-rank scatter — no dynamic allocation; the pool
merge is padded-concat + dedup handled inside ``unified_prune``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import intervals as iv
from repro.core.candidates import generate_candidates
from repro.core.exact import DenseGraph
from repro.core.prune import unified_prune


@dataclasses.dataclass(frozen=True)
class UGConfig:
    """Build hyper-parameters; defaults follow the paper's §5.1 (scaled names).

    Paper defaults: ef_spatial=128, ef_attribute=300, max_edges_IF =
    max_edges_IS = 256, 5 refinement iterations.
    """

    ef_spatial: int = 128
    ef_attribute: int = 300
    max_edges_if: int = 256
    max_edges_is: int = 256
    iterations: int = 5
    repair_width: int = 32          # W_max: bounded repair set per node
    alpha: float = 1.0              # RNG slack (1.0 = paper-faithful)
    unified: bool = True            # False = classical interval-agnostic RNG
    nnd_iters: int = 6
    exact_spatial: bool = False     # exact KNN candidates (small n oracle)
    block: int = 1024               # nodes pruned per jitted block


def scatter_repairs(
    w_ids: jnp.ndarray, v_ids: jnp.ndarray, n: int, width: int
) -> jnp.ndarray:
    """Build fixed-width repair sets W(w) from flat (w, v) pairs (Alg. 2 l.11-12)."""
    valid = (w_ids >= 0) & (v_ids >= 0)
    seg = jnp.where(valid, w_ids, n)
    order = jnp.argsort(seg, stable=True)
    seg_s = seg[order]
    v_s = v_ids[order]
    first = jnp.searchsorted(seg_s, seg_s, side="left")
    rank = jnp.arange(seg_s.shape[0]) - first
    ok = (seg_s < n) & (rank < width)
    out = jnp.full((n + 1, width), -1, jnp.int32)
    out = out.at[jnp.where(ok, seg_s, n), jnp.where(ok, rank, 0)].set(
        jnp.where(ok, v_s, -1), mode="drop"
    )
    return out[:n]


def _prune_all(
    x: jnp.ndarray,
    intervals: jnp.ndarray,
    cand: jnp.ndarray,
    cfg: UGConfig,
    progress: Callable[[str], None] | None = None,
):
    """One full pruning sweep (Alg. 2 lines 8-9) over all nodes, blocked."""
    n = x.shape[0]
    keep = cfg.max_edges_if + cfg.max_edges_is
    keep = min(keep, cand.shape[1])
    nbrs_l, stat_l, wpair_w, wpair_v = [], [], [], []
    for s in range(0, n, cfg.block):
        u = jnp.arange(s, min(s + cfg.block, n), dtype=jnp.int32)
        res = unified_prune(
            u, cand[s : s + cfg.block], x, intervals,
            m_if=cfg.max_edges_if, m_is=cfg.max_edges_is,
            alpha=cfg.alpha, unified=cfg.unified,
        )
        # Compact retained neighbors to the front (ascending distance).
        score = jnp.where(res.status > 0, res.dist, jnp.inf)
        order = jnp.argsort(score, axis=-1)[:, :keep]
        ids = jnp.take_along_axis(res.order, order, axis=-1)
        st = jnp.take_along_axis(res.status, order, axis=-1)
        live = jnp.isfinite(jnp.take_along_axis(score, order, axis=-1))
        nbrs_l.append(jnp.where(live, ids, -1))
        stat_l.append(jnp.where(live, st, 0))
        # Repair pairs (w, v): witness gets the pruned endpoint.
        for rep in (res.repair_if, res.repair_is):
            wpair_w.append(rep.reshape(-1))
            wpair_v.append(jnp.where(rep >= 0, res.order, -1).reshape(-1))
        if progress is not None:
            progress(f"prune block {s}:{min(s + cfg.block, n)}")
    nbrs = jnp.concatenate(nbrs_l)
    stat = jnp.concatenate(stat_l)
    return nbrs, stat, jnp.concatenate(wpair_w), jnp.concatenate(wpair_v)


def build_ug(
    key: jax.Array,
    x: jnp.ndarray,
    intervals: jnp.ndarray,
    cfg: UGConfig = UGConfig(),
    progress: Callable[[str], None] | None = None,
) -> DenseGraph:
    """Paper Alg. 1 + Alg. 2: candidate generation then T pruning iterations."""
    n = x.shape[0]
    cand = generate_candidates(
        key, x, intervals,
        ef_spatial=cfg.ef_spatial, ef_attribute=cfg.ef_attribute,
        nnd_iters=cfg.nnd_iters, exact_spatial=cfg.exact_spatial,
    )
    if progress is not None:
        progress(f"candidates: shape {cand.shape}")

    repair = jnp.full((n, cfg.repair_width), -1, jnp.int32)
    nbrs = stat = None
    for t in range(cfg.iterations):
        pool = cand if t == 0 else jnp.concatenate([cand, repair], axis=1)
        nbrs, stat, w_w, w_v = _prune_all(x, intervals, pool, cfg, progress)
        cand = nbrs  # retained neighbors seed the next round (Alg. 2 line 10)
        repair = scatter_repairs(w_w, w_v, n, cfg.repair_width)
        if progress is not None:
            deg = float(jnp.mean(jnp.sum(nbrs >= 0, axis=1)))
            progress(f"iter {t + 1}/{cfg.iterations}: mean degree {deg:.1f}")

    # Trim trailing all-pad columns.
    live_cols = int(jnp.max(jnp.sum(nbrs >= 0, axis=1)))
    live_cols = max(live_cols, 1)
    return DenseGraph(nbrs[:, :live_cols], stat[:, :live_cols])
