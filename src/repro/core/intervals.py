"""Interval algebra for interval-aware ANN search (paper §2.1, §3).

Every object carries an interval ``I_o = [l, r]`` with ``l <= r``; every query
carries ``q.I = [a_l, a_r]``. The four query semantics of the paper reduce to
two predicates:

* IFANN:  ``I_o ⊆ q.I``             (interval-filtered)
* ISANN:  ``q.I ⊆ I_o``             (interval-stabbing)
* RFANN:  IFANN with degenerate object intervals ``I_o = [a, a]``
* RSANN:  ISANN with degenerate query interval  ``q.I = [t, t]``

The URNG witness conditions (Def. 3.1) are:

* ``Φ_IF(u, v, w): I_w ⊆ I_u ∪ I_v``   with ``∪`` the *hull* (footnote 2)
* ``Φ_IS(u, v, w): I_u ∩ I_v ⊆ I_w``   considered only when ``I_u ∩ I_v ≠ ∅``

All functions broadcast: intervals are arrays whose last axis has size 2
(``[..., 0] = l``, ``[..., 1] = r``).
"""
from __future__ import annotations

import enum

import jax.numpy as jnp

# Semantic bit layout of the per-edge status byte (paper Def. 3.1 bitmask).
FLAG_IF = 1  # bit 0: edge active for interval-filtered (IF) semantics
FLAG_IS = 2  # bit 1: edge active for interval-stabbing (IS) semantics
FLAG_BOTH = FLAG_IF | FLAG_IS


class Semantics(enum.Enum):
    """Query semantics; RF/RS are degenerate IF/IS (paper §2.1)."""

    IF = "IF"
    IS = "IS"
    RF = "RF"  # scalar-attribute filtering == IF with point object intervals
    RS = "RS"  # stabbing == IS with point query interval

    @property
    def flag(self) -> int:
        return FLAG_IF if self in (Semantics.IF, Semantics.RF) else FLAG_IS


def hull(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Interval hull ``a ∪ b = [min(l_a, l_b), max(r_a, r_b)]`` (footnote 2)."""
    lo = jnp.minimum(a[..., 0], b[..., 0])
    hi = jnp.maximum(a[..., 1], b[..., 1])
    return jnp.stack([lo, hi], axis=-1)


def intersection(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Interval intersection (may be empty: ``l > r``)."""
    lo = jnp.maximum(a[..., 0], b[..., 0])
    hi = jnp.minimum(a[..., 1], b[..., 1])
    return jnp.stack([lo, hi], axis=-1)


def is_empty(a: jnp.ndarray) -> jnp.ndarray:
    return a[..., 0] > a[..., 1]


def contains(outer: jnp.ndarray, inner: jnp.ndarray) -> jnp.ndarray:
    """``inner ⊆ outer`` (both non-degenerate interval arrays)."""
    return (outer[..., 0] <= inner[..., 0]) & (inner[..., 1] <= outer[..., 1])


def phi_if(iu: jnp.ndarray, iv: jnp.ndarray, iw: jnp.ndarray) -> jnp.ndarray:
    """IF witness condition ``I_w ⊆ I_u ∪ I_v`` (Def. 3.1)."""
    return contains(hull(iu, iv), iw)


def phi_is(iu: jnp.ndarray, iv: jnp.ndarray, iw: jnp.ndarray) -> jnp.ndarray:
    """IS witness condition ``I_u ∩ I_v ⊆ I_w``; empty intersections are
    excluded upstream (Alg. 3 lines 7-8 clear the IS bit when ``I_u∩I_v=∅``)."""
    inter = intersection(iu, iv)
    nonempty = ~is_empty(inter)
    return nonempty & (iw[..., 0] <= inter[..., 0]) & (iw[..., 1] >= inter[..., 1])


def predicate(sem: Semantics, obj: jnp.ndarray, query: jnp.ndarray) -> jnp.ndarray:
    """Query validity predicate; ``obj`` broadcasts against ``query``.

    RF treats ``obj`` as point intervals (callers store scalars as [a, a]);
    RS treats ``query`` as a point interval ([t, t]).  Both reduce to IF/IS.
    """
    if sem in (Semantics.IF, Semantics.RF):
        return contains(query, obj)
    return contains(obj, query)


def query_valid_mask(sem: Semantics, intervals: jnp.ndarray, q_interval: jnp.ndarray) -> jnp.ndarray:
    """Validity of every object for one query: (n, 2) x (2,) -> (n,) bool."""
    return predicate(sem, intervals, q_interval[None, :])


# ---------------------------------------------------------------------------
# Runtime (per-query) semantics: sem-flag arrays instead of a static enum.
#
# All four semantics reduce to two predicate directions (§2.1), so one int32
# flag per query — FLAG_IF for IF/RF, FLAG_IS for IS/RS — fully determines
# both the validity predicate and which edge-status bit gates traversal.
# Making the flag a traced array (not a static argname) lets one compiled
# search program serve a mixed IF/IS/RF/RS batch (DESIGN.md §10).
# ---------------------------------------------------------------------------
def as_sem_flags(sem, batch_size: int) -> jnp.ndarray:
    """Normalize a semantics spec to a ``(batch_size,)`` int32 flag array.

    Accepts one :class:`Semantics` (broadcast), a sequence of
    ``Semantics``/flag ints (one per query), or an existing flag array.
    Host-side values (anything but a traced array) are validated to be
    ``FLAG_IF`` or ``FLAG_IS`` — flag 0 would silently fail every edge gate
    and return all-NULL rows, flag 3 would traverse both semantics; tracers
    are passed through unchecked (the caller owns them).
    """
    import jax
    import numpy as np

    if isinstance(sem, Semantics):
        return jnp.full((batch_size,), sem.flag, jnp.int32)
    if isinstance(sem, (list, tuple)):
        sem = jnp.asarray(
            [s.flag if isinstance(s, Semantics) else int(s) for s in sem],
            jnp.int32,
        )
    if not isinstance(sem, jax.core.Tracer):
        bad = sorted(set(np.unique(np.asarray(sem)).tolist()) - {FLAG_IF, FLAG_IS})
        if bad:
            raise ValueError(
                f"sem flags must be FLAG_IF ({FLAG_IF}) or FLAG_IS "
                f"({FLAG_IS}), got {bad}")
    arr = jnp.asarray(sem).astype(jnp.int32)
    if arr.ndim != 1 or arr.shape[0] != batch_size:
        raise ValueError(f"sem flags shape {arr.shape} != ({batch_size},)")
    return arr


def is_filter_flag(flags: jnp.ndarray) -> jnp.ndarray:
    """True where the flag selects the containment direction of IF/RF."""
    return (flags & FLAG_IF) > 0


def predicate_by_flag(flags: jnp.ndarray, obj: jnp.ndarray, query: jnp.ndarray) -> jnp.ndarray:
    """Flag-driven :func:`predicate`: ``flags`` broadcasts against the
    leading dims of ``obj``/``query`` (last axis of those has size 2).

    Evaluates both containment directions and selects per element — the
    selected lane is computed exactly as the static path computes it, so a
    uniform-flag batch is bitwise equal to :func:`predicate`.
    """
    return jnp.where(
        is_filter_flag(flags), contains(query, obj), contains(obj, query)
    )


def query_valid_mask_by_flag(
    flags: jnp.ndarray, intervals: jnp.ndarray, q_intervals: jnp.ndarray
) -> jnp.ndarray:
    """Per-query validity of every object: (B,) x (n, 2) x (B, 2) -> (B, n)."""
    return predicate_by_flag(
        flags[:, None], intervals[None, :, :], q_intervals[:, None, :]
    )


def sample_uniform_intervals(key, n: int, dtype=jnp.float32) -> jnp.ndarray:
    """Uniform interval model of the paper's complexity analysis (§3.2, App. A):
    endpoints are two i.i.d. U(0,1) draws per object, sorted."""
    import jax

    pts = jax.random.uniform(key, (n, 2), dtype=dtype)
    return jnp.sort(pts, axis=-1)


def sample_point_intervals(key, n: int, dtype=jnp.float32) -> jnp.ndarray:
    """Degenerate intervals for the RFANN special case (scalar attributes)."""
    import jax

    a = jax.random.uniform(key, (n, 1), dtype=dtype)
    return jnp.concatenate([a, a], axis=-1)
