"""IndexStore — the one typed pytree every layer of the index shares.

Before this module the repo carried three divergent index representations
(the dense ``UGIndex`` field bundle, ``ShardedIndexArrays``, and the
``ServeEngine``'s attached copies) and every layer hand-carried
``(x, intervals, nbrs, status, alive, …)`` tuples.  ``IndexStore`` unifies
them (DESIGN.md §12): one registered pytree holding

* a **vector plane** — the scoring representation of the corpus vectors.
  Four plane tags: ``f32`` (paper-faithful), ``bf16`` (2 bytes/dim, cast
  in-register by the existing expand-score kernels), ``int8``
  (scalar-quantized, per-dimension affine ``x ≈ q·scale + zero``,
  dequantized in-register by the quantized kernel twins), and ``pq``
  (product-quantized: ``m`` subspaces of ``d/m`` dims, 256 k-means
  centroids each, one uint8 code per subspace, scored through per-query
  lookup tables — DESIGN.md §14);
* an optional **fp32 rerank plane** — exact vectors used only to re-score
  the final beam, so a quantized scan plane keeps f32-grade top-k;
* the graph (``nbrs``/``status``), the interval column, the entry
  structure (Alg. 5), and the streaming allocator state (``alive``/``free``
  masks, DESIGN.md §11).

Being a pytree, the store traces through ``jax.jit`` and ``shard_map``
unchanged — the sharded serving path holds the *same* structure with
row-sharded leaves (core/sharded.py), and the serve engine holds it by
reference (zero duplicate device copies; tests/test_store_planes.py pins
buffer identity).

Quantization scheme (``int8``): per-dimension affine with
``zero = (min + max) / 2`` and ``scale = (max - min) / 254`` (floored at
1e-8), so codes span ``[-127, 127]`` symmetrically around the per-dim
center.  Parameters are frozen at encode time; streaming inserts encode
new rows under the frozen parameters (re-centering would invalidate every
stored code).  Decode error is ≤ ``scale/2`` per dimension.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.entry import EntryIndex, build_entry_index
from repro.core.exact import DenseGraph

PLANE_TAGS = ("f32", "bf16", "int8", "pq")
_PLANE_DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16, "int8": jnp.int8}
_QMAX = 127.0  # int8 code range is [-127, 127]; -128 stays unused (symmetric)
PQ_K = 256     # centroids per subspace — one uint8 code each
_PQ_TRAIN_SAMPLE = 4096
_PQ_TRAIN_ITERS = 10


def quantization_params(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-dimension affine (scale, zero) from the corpus column ranges."""
    x32 = x.astype(jnp.float32)
    lo = jnp.min(x32, axis=0)
    hi = jnp.max(x32, axis=0)
    zero = (lo + hi) * 0.5
    scale = jnp.maximum((hi - lo) / (2.0 * _QMAX), 1e-8)
    return scale, zero


def default_pq_m(d: int) -> int:
    """Default subspace count: ~8 dims per subspace, reduced until it
    divides ``d`` evenly (d=24 → m=3, d=16 → m=2, d=12 → m=1)."""
    m = max(d // 8, 1)
    while d % m:
        m -= 1
    return m


def _pq_sq_dists(xs: jnp.ndarray, cb: jnp.ndarray) -> jnp.ndarray:
    """(m, s, K) squared distances from subvectors to centroids."""
    return (
        jnp.sum(xs * xs, axis=-1)[:, :, None]
        - 2.0 * jnp.einsum("msd,mkd->msk", xs, cb)
        + jnp.sum(cb * cb, axis=-1)[:, None, :]
    )


@jax.jit
def _pq_lloyd(xs: jnp.ndarray, cb: jnp.ndarray) -> jnp.ndarray:
    """``_PQ_TRAIN_ITERS`` Lloyd iterations over every subspace at once.
    Empty clusters keep their previous centroid (no reseeding — keeps the
    training deterministic and jit-friendly)."""

    def step(cb, _):
        assign = jnp.argmin(_pq_sq_dists(xs, cb), axis=-1)          # (m, s)
        onehot = jax.nn.one_hot(assign, cb.shape[1], dtype=jnp.float32)
        counts = jnp.sum(onehot, axis=1)                            # (m, K)
        sums = jnp.einsum("msk,msd->mkd", onehot, xs)               # (m, K, dsub)
        new = sums / jnp.maximum(counts[..., None], 1.0)
        return jnp.where((counts > 0)[..., None], new, cb), None

    cb, _ = jax.lax.scan(step, cb, None, length=_PQ_TRAIN_ITERS)
    return cb


def train_pq_codebooks(
    x: jnp.ndarray, m: int | None = None, *, seed: int = 0
) -> jnp.ndarray:
    """On-device k-means codebook training: ``(m, 256, d/m)`` f32.

    Trains on a deterministic sample of ≤ ``_PQ_TRAIN_SAMPLE`` rows,
    initialized from distinct permuted sample rows per subspace.  The
    result is **frozen** at encode time exactly like the int8 qparams —
    streaming inserts encode new rows under the frozen codebooks
    (retraining would invalidate every stored code)."""
    x32 = jnp.asarray(x).astype(jnp.float32)
    n, d = x32.shape
    if m is None:
        m = default_pq_m(d)
    if m < 1 or d % m:
        raise ValueError(f"pq subspace count m={m} must divide d={d}")
    s = max(min(n, _PQ_TRAIN_SAMPLE), 1)
    perm = jax.random.permutation(jax.random.key(seed), max(n, 1))[:s]
    xs = x32[perm].reshape(s, m, d // m).transpose(1, 0, 2)  # (m, s, dsub)
    init = xs[:, jnp.arange(PQ_K) % s, :]                    # (m, K, dsub)
    return _pq_lloyd(xs, init)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class VectorPlane:
    """One storage representation of the corpus vectors.

    ``tag`` is pytree aux data (a compile-time constant), so kernel
    dispatch on the plane dtype never retraces on array contents — only a
    different tag compiles a different program.
    """

    tag: str                        # "f32" | "bf16" | "int8" | "pq"
    data: jnp.ndarray               # (cap, d) in the plane dtype; pq: (cap, m) u8
    scale: jnp.ndarray | None = None  # (d,) f32 — int8 only
    zero: jnp.ndarray | None = None   # (d,) f32 — int8 only
    codebooks: jnp.ndarray | None = None  # (m, 256, d/m) f32 — pq only

    def tree_flatten(self):
        return (self.data, self.scale, self.zero, self.codebooks), self.tag

    @classmethod
    def tree_unflatten(cls, tag, children):
        data, scale, zero, codebooks = children
        return cls(tag, data, scale, zero, codebooks)

    # ------------------------------------------------------------- encode
    @classmethod
    def encode(
        cls, x: jnp.ndarray, tag: str, qparams=None, *, pq_m: int | None = None
    ) -> "VectorPlane":
        """Encode f32 vectors into a plane; ``qparams`` overrides the
        derived int8 (scale, zero) — or, for ``pq``, the trained
        ``(m, 256, d/m)`` codebooks — used to re-encode rows of a grown
        capacity under frozen parameters."""
        if tag not in PLANE_TAGS:
            raise ValueError(f"unknown plane tag {tag!r} (choices {PLANE_TAGS})")
        x = jnp.asarray(x)
        if tag == "f32":
            data = x if x.dtype == jnp.float32 else x.astype(jnp.float32)
            return cls(tag, data)
        if tag == "bf16":
            return cls(tag, x.astype(jnp.bfloat16))
        if tag == "pq":
            cb = train_pq_codebooks(x, pq_m) if qparams is None else jnp.asarray(qparams)
            plane = cls(tag, jnp.zeros((0, cb.shape[0]), jnp.uint8), codebooks=cb)
            return dataclasses.replace(plane, data=plane.encode_rows(x))
        scale, zero = quantization_params(x) if qparams is None else qparams
        plane = cls(tag, jnp.zeros((0,), jnp.int8), scale, zero)
        return dataclasses.replace(plane, data=plane.encode_rows(x))

    def encode_rows(self, rows: jnp.ndarray) -> jnp.ndarray:
        """Encode f32 rows into this plane's dtype under its frozen params
        (streaming inserts; capacity growth)."""
        rows = jnp.asarray(rows)
        if self.tag == "f32":
            return rows if rows.dtype == jnp.float32 else rows.astype(jnp.float32)
        if self.tag == "bf16":
            return rows.astype(jnp.bfloat16)
        if self.tag == "pq":
            m, _, dsub = self.codebooks.shape
            r = rows.astype(jnp.float32).reshape(rows.shape[0], m, dsub)
            d2 = _pq_sq_dists(r.transpose(1, 0, 2), self.codebooks)  # (m, b, K)
            return jnp.argmin(d2, axis=-1).T.astype(jnp.uint8)       # (b, m)
        q = jnp.round((rows.astype(jnp.float32) - self.zero) / self.scale)
        return jnp.clip(q, -_QMAX, _QMAX).astype(jnp.int8)

    # ------------------------------------------------------------- decode
    def _pq_decode_codes(self, codes: jnp.ndarray) -> jnp.ndarray:
        """(b, m) uint8 codes → (b, d) f32 centroid reconstructions."""
        m, k, dsub = self.codebooks.shape
        flat = self.codebooks.reshape(m * k, dsub)
        idx = codes.astype(jnp.int32) + (jnp.arange(m, dtype=jnp.int32) * k)[None, :]
        return flat[idx].reshape(codes.shape[0], m * dsub)

    def decode(self) -> jnp.ndarray:
        """The (cap, d) f32 view.  Identity (same buffer) for ``f32``."""
        if self.tag == "f32":
            return self.data
        if self.tag == "bf16":
            return self.data.astype(jnp.float32)
        if self.tag == "pq":
            return self._pq_decode_codes(self.data)
        return self.data.astype(jnp.float32) * self.scale + self.zero

    def decode_rows(self, ids: jnp.ndarray) -> jnp.ndarray:
        """Gather rows then dequantize — the (|ids|, d) f32 view of a row
        subset without materializing the full decoded plane."""
        rows = self.data[ids]
        if self.tag == "f32":
            return rows
        if self.tag == "bf16":
            return rows.astype(jnp.float32)
        if self.tag == "pq":
            return self._pq_decode_codes(rows)
        return rows.astype(jnp.float32) * self.scale + self.zero

    # -------------------------------------------------------------- stats
    @property
    def dim(self) -> int:
        if self.tag == "pq":
            m, _, dsub = self.codebooks.shape
            return m * dsub
        return self.data.shape[-1]

    def memory_bytes(self) -> int:
        b = self.data.size * self.data.dtype.itemsize
        for a in (self.scale, self.zero, self.codebooks):
            if a is not None:
                b += a.size * a.dtype.itemsize
        return int(b)

    def bytes_per_vector(self, n_live: int | None = None) -> float:
        """Amortized plane bytes per stored vector (qparams/codebooks
        included).  ``n_live`` is the live-row count; it defaults to the
        row capacity, but callers that grew the store must pass the live
        count — capacity doubling would otherwise silently halve the
        reported bytes/vec (the store itself owns the alive mask, so the
        plane cannot derive liveness here)."""
        n = self.data.shape[0] if n_live is None else n_live
        return self.memory_bytes() / max(n, 1)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class IndexStore:
    """The unified index pytree: planes + intervals + graph + entry +
    allocator.  Frozen — every mutation is a functional ``replace``."""

    plane: VectorPlane              # scoring plane (hot path)
    rerank: VectorPlane | None      # optional exact f32 plane (final top-k)
    intervals: jnp.ndarray          # (cap, 2)
    nbrs: jnp.ndarray               # (cap, M) int32, -1 padded
    status: jnp.ndarray             # (cap, M) uint8 semantic bitmask
    entry: EntryIndex | None        # Alg. 5 structure (None: built on use,
    #                                 e.g. per shard inside shard_map)
    alive: jnp.ndarray | None = None  # (cap,) bool; None = all live
    free: jnp.ndarray | None = None   # (cap,) bool; None = none free

    def tree_flatten(self):
        return (
            self.plane, self.rerank, self.intervals, self.nbrs, self.status,
            self.entry, self.alive, self.free,
        ), None

    @classmethod
    def tree_unflatten(cls, _, children):
        return cls(*children)

    # -------------------------------------------------------------- views
    @property
    def capacity(self) -> int:
        return self.nbrs.shape[0]

    @property
    def dim(self) -> int:
        return self.plane.dim

    @property
    def graph(self) -> DenseGraph:
        """DenseGraph view over the same buffers (no copy)."""
        return DenseGraph(self.nbrs, self.status)

    def live_count(self) -> int:
        """Number of live rows (capacity when no alive mask is set)."""
        if self.alive is None:
            return self.capacity
        return int(jnp.sum(self.alive))

    def vectors_f32(self) -> jnp.ndarray:
        """Best-precision f32 vectors: the rerank plane when present, else
        the decoded scan plane.  Identity (same buffer) for an f32 plane."""
        if self.rerank is not None:
            return self.rerank.data
        return self.plane.decode()

    def replace(self, **kw) -> "IndexStore":
        return dataclasses.replace(self, **kw)

    # ---------------------------------------------------- slot allocator
    def masks(self) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Materialize the lazy all-live / none-free allocator masks."""
        cap = self.capacity
        alive = self.alive if self.alive is not None else jnp.ones((cap,), bool)
        free = self.free if self.free is not None else jnp.zeros((cap,), bool)
        return alive, free

    def widen_rows(self, m_full: int) -> "IndexStore":
        """Re-widen the neighbor rows to the degree-budget bound
        ``m_if + m_is`` (the build trims trailing dead columns; streaming
        updates need that headroom back — DESIGN.md §11)."""
        r = m_full - self.nbrs.shape[1]
        if r <= 0:
            return self
        return self.replace(
            nbrs=jnp.pad(self.nbrs, ((0, 0), (0, r)), constant_values=-1),
            status=jnp.pad(self.status, ((0, 0), (0, r))),
        )

    def grow(self, need: int, m_full: int) -> "IndexStore":
        """Capacity-doubling growth: a store with materialized masks, rows
        widened to ``m_full``, and ≥ ``need`` free slots.  Virgin slots get
        inverted intervals ``[2, -2]`` (no predicate ever matches), ``-1``
        neighbor rows, zero plane codes, and ``free=True``."""
        from repro.kernels.beam_merge import next_pow2

        alive, free = self.masks()
        out = self.widen_rows(m_full).replace(alive=alive, free=free)
        cap = self.capacity
        n_free = int(jnp.sum(free))
        if n_free >= need:
            return out
        new_cap = max(2 * cap, next_pow2(cap + need - n_free))
        r = new_cap - cap
        pad_plane = lambda p: None if p is None else dataclasses.replace(
            p, data=jnp.pad(p.data, ((0, r), (0, 0)))
        )
        dead_iv = jnp.broadcast_to(
            jnp.asarray([2.0, -2.0], self.intervals.dtype), (r, 2)
        )
        return out.replace(
            entry=None,  # capacity growth invalidates it; insert rebuilds
            plane=pad_plane(out.plane),
            rerank=pad_plane(out.rerank),
            intervals=jnp.concatenate([out.intervals, dead_iv]),
            nbrs=jnp.pad(out.nbrs, ((0, r), (0, 0)), constant_values=-1),
            status=jnp.pad(out.status, ((0, r), (0, 0))),
            alive=jnp.pad(alive, (0, r)),
            free=jnp.pad(free, (0, r), constant_values=True),
        )

    # -------------------------------------------------------------- stats
    def memory_bytes(self) -> dict:
        """Per-component byte counts (the memory-footprint table's source)."""
        ent = self.entry
        out = {
            "plane": self.plane.memory_bytes(),
            "rerank": 0 if self.rerank is None else self.rerank.memory_bytes(),
            "graph": int(
                self.nbrs.size * self.nbrs.dtype.itemsize
                + self.status.size * self.status.dtype.itemsize
            ),
            "intervals": int(
                self.intervals.size * self.intervals.dtype.itemsize
            ),
            "entry": 0 if ent is None else int(
                sum(a.size * a.dtype.itemsize for a in ent)
            ),
            "masks": (0 if self.alive is None else self.capacity)
            + (0 if self.free is None else self.capacity),
        }
        out["total"] = sum(out.values())
        return out


def make_store(
    x,
    intervals,
    nbrs,
    status,
    *,
    dtype: str = "f32",
    rerank: bool = False,
    qparams=None,
    pq_m: int | None = None,
    entry: EntryIndex | None = None,
    build_entry: bool = True,
    alive: jnp.ndarray | None = None,
    free: jnp.ndarray | None = None,
) -> IndexStore:
    """Assemble an :class:`IndexStore` from f32 vectors + graph arrays.

    ``dtype`` selects the scan plane; ``rerank=True`` attaches the exact
    f32 plane for final-top-k re-scoring.  ``build_entry=False`` leaves
    ``entry=None`` (per-shard stores build theirs inside ``shard_map``).
    """
    x = jnp.asarray(x)
    intervals = jnp.asarray(intervals)
    if entry is None and build_entry:
        entry = build_entry_index(intervals, node_mask=alive)
    return IndexStore(
        plane=VectorPlane.encode(x, dtype, qparams, pq_m=pq_m),
        rerank=VectorPlane.encode(x, "f32") if rerank else None,
        intervals=intervals,
        nbrs=jnp.asarray(nbrs),
        status=jnp.asarray(status),
        entry=entry,
        alive=alive,
        free=free,
    )
