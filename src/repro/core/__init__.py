"""URNG/UG — the paper's primary contribution (unified interval-aware graph
index) implemented as composable JAX modules.  See DESIGN.md §1-2."""
from repro.core.intervals import FLAG_BOTH, FLAG_IF, FLAG_IS, Semantics
from repro.core.build import UGConfig, build_ug
from repro.core.exact import DenseGraph, build_exact, greedy_monotonic_path
from repro.core.entry import EntryIndex, build_entry_index, get_entry, get_entry_batch
from repro.core.index import UGIndex, recall
from repro.core.search import SearchResult, beam_search, brute_force, search

__all__ = [
    "FLAG_BOTH", "FLAG_IF", "FLAG_IS", "Semantics",
    "UGConfig", "build_ug", "DenseGraph", "build_exact",
    "greedy_monotonic_path", "EntryIndex", "build_entry_index", "get_entry",
    "get_entry_batch",
    "UGIndex", "recall", "SearchResult", "beam_search", "brute_force", "search",
]
