"""URNG/UG — the paper's primary contribution (unified interval-aware graph
index) implemented as composable JAX modules.  See DESIGN.md §1-2."""
from repro.core.intervals import FLAG_BOTH, FLAG_IF, FLAG_IS, Semantics, as_sem_flags
from repro.core.build import UGConfig, build_ug
from repro.core.exact import DenseGraph, build_exact, greedy_monotonic_path
from repro.core.entry import (
    EntryIndex, build_entry_index, get_entry, get_entry_batch,
    get_entry_batch_flags, get_entry_flags,
)
from repro.core.store import IndexStore, VectorPlane, make_store
from repro.core.index import UGIndex, recall
from repro.core.search import (
    SearchResult, beam_search, beam_search_flags, brute_force, search,
    search_mixed,
)
from repro.core.updates import (
    compact, delete_batch, insert, insert_batch, repair_deleted,
    update_memory_profile,
)

__all__ = [
    "FLAG_BOTH", "FLAG_IF", "FLAG_IS", "Semantics", "as_sem_flags",
    "UGConfig", "build_ug", "DenseGraph", "build_exact",
    "greedy_monotonic_path", "EntryIndex", "build_entry_index", "get_entry",
    "get_entry_batch", "get_entry_batch_flags", "get_entry_flags",
    "IndexStore", "VectorPlane", "make_store",
    "UGIndex", "recall", "SearchResult", "beam_search", "beam_search_flags",
    "brute_force", "search", "search_mixed",
    "compact", "delete_batch", "insert", "insert_batch", "repair_deleted",
    "update_memory_profile",
]
