"""Unified interval-aware pruning (paper Alg. 3 / Def. 3.1).

The single routine :func:`unified_prune` implements the paper's
``UnifiedPrune`` for a *block* of nodes at once.  It is the workhorse of both

* the **exact URNG reference** (candidate set = all other nodes, ``M = n``,
  which is precisely Def. 3.1 evaluated in per-node ascending-distance order),
* the **practical UG build** (bounded candidate pools from Alg. 1 + repair
  sets from Alg. 2).

TPU adaptation (see DESIGN.md §2): the per-candidate scan of Alg. 3 is a
``lax.fori_loop`` whose witness check is a *vectorized* mask over all already
retained candidates, and the whole thing is ``vmap``-ed over a block of nodes.
Distances are blocked matmuls (fp32 accumulation).  Classical RNG pruning
(used by the post-filtering baseline) is the same routine with the semantic
witness conditions forced to ``True``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import intervals as iv


class PruneResult(NamedTuple):
    """Per-node pruning output, aligned to distance-sorted candidate order."""

    order: jnp.ndarray      # (B, C) int32 candidate ids sorted by δ(u, ·); -1 pad
    dist: jnp.ndarray       # (B, C) f32 squared distance to u (+inf for pads)
    status: jnp.ndarray     # (B, C) uint8 semantic bitmask (0 = fully pruned)
    repair_if: jnp.ndarray  # (B, C) int32 global id of the IF witness or -1
    repair_is: jnp.ndarray  # (B, C) int32 global id of the IS witness or -1


def squared_dist(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Blocked ‖a−b‖² via the matmul identity; fp32 accumulation on the MXU."""
    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    an = jnp.sum(a32 * a32, axis=-1)
    bn = jnp.sum(b32 * b32, axis=-1)
    ip = jnp.einsum("...id,...jd->...ij", a32, b32, preferred_element_type=jnp.float32)
    d = an[..., :, None] + bn[..., None, :] - 2.0 * ip
    return jnp.maximum(d, 0.0)


def _dedup_sorted_by_distance(cand: jnp.ndarray, dist: jnp.ndarray):
    """Mask duplicate candidate ids (keep the first), then sort by distance.

    ``cand`` is (C,) int32 with -1 padding; ``dist`` is (C,) f32.
    """
    big = jnp.float32(jnp.inf)
    invalid = cand < 0
    dist = jnp.where(invalid, big, dist)
    # Detect duplicates by sorting ids and flagging repeats.
    id_order = jnp.argsort(cand)
    sorted_ids = cand[id_order]
    dup_sorted = jnp.concatenate(
        [jnp.zeros((1,), bool), sorted_ids[1:] == sorted_ids[:-1]]
    ) & (sorted_ids >= 0)
    dup = jnp.zeros_like(dup_sorted).at[id_order].set(dup_sorted)
    dist = jnp.where(dup, big, dist)
    order = jnp.argsort(dist)
    return cand[order], dist[order]


def _prune_one_node(
    i_u: jnp.ndarray,        # (2,) interval of u
    cand: jnp.ndarray,       # (C,) candidate ids (dedup'd, distance-sorted)
    d_uc: jnp.ndarray,       # (C,) squared distances δ²(u, c)
    d_cc: jnp.ndarray,       # (C, C) pairwise squared distances among candidates
    i_c: jnp.ndarray,        # (C, 2) candidate intervals
    m_if: int,
    m_is: int,
    alpha: float,
    unified: bool,
):
    """Algorithm 3 for one node, with vectorized witness checks."""
    C = cand.shape[0]
    valid = (cand >= 0) & jnp.isfinite(d_uc)

    if unified:
        # Φ matrices over (candidate v, witness w) pairs; row = v, col = w.
        iu_b = jnp.broadcast_to(i_u, (C, C, 2))
        iv_b = jnp.broadcast_to(i_c[:, None, :], (C, C, 2))
        iw_b = jnp.broadcast_to(i_c[None, :, :], (C, C, 2))
        phi_if_mat = iv.phi_if(iu_b, iv_b, iw_b)
        phi_is_mat = iv.phi_is(iu_b, iv_b, iw_b)
        overlap_uv = ~iv.is_empty(iv.intersection(jnp.broadcast_to(i_u, (C, 2)), i_c))
    else:
        # Classical RNG pruning: semantic conditions always hold (both bits
        # follow pure geometry — used for interval-agnostic baselines).
        phi_if_mat = jnp.ones((C, C), bool)
        phi_is_mat = jnp.ones((C, C), bool)
        overlap_uv = jnp.ones((C,), bool)

    alpha2 = jnp.float32(alpha) ** 2
    jrange = jnp.arange(C)

    def body(t, state):
        act_if, act_is, cnt_if, cnt_is, rep_if, rep_is = state
        v_ok = valid[t]
        s_if = v_ok
        s_is = v_ok & overlap_uv[t]

        # Witness scan (Alg. 3 lines 9-17), vectorized over retained prefix.
        geo = (jrange < t) & (alpha2 * d_cc[t] < d_uc[t])
        wit_if = geo & act_if & phi_if_mat[t]
        wit_is = geo & act_is & phi_is_mat[t]
        pruned_if = jnp.any(wit_if)
        pruned_is = jnp.any(wit_is)
        j_if = jnp.argmax(wit_if)  # first witness in scan order
        j_is = jnp.argmax(wit_is)

        keep_if = s_if & ~pruned_if
        keep_is = s_is & ~pruned_is
        # Semantic degree budgets (lines 18-21).
        keep_if = keep_if & (cnt_if < m_if)
        keep_is = keep_is & (cnt_is < m_is)
        cnt_if = cnt_if + keep_if.astype(jnp.int32)
        cnt_is = cnt_is + keep_is.astype(jnp.int32)

        act_if = act_if.at[t].set(keep_if)
        act_is = act_is.at[t].set(keep_is)
        rep_if = rep_if.at[t].set(jnp.where(s_if & pruned_if, j_if, -1))
        rep_is = rep_is.at[t].set(jnp.where(s_is & pruned_is, j_is, -1))
        return act_if, act_is, cnt_if, cnt_is, rep_if, rep_is

    init = (
        jnp.zeros((C,), bool),
        jnp.zeros((C,), bool),
        jnp.int32(0),
        jnp.int32(0),
        jnp.full((C,), -1, jnp.int32),
        jnp.full((C,), -1, jnp.int32),
    )
    act_if, act_is, _, _, rep_if, rep_is = jax.lax.fori_loop(0, C, body, init)

    status = act_if.astype(jnp.uint8) * iv.FLAG_IF + act_is.astype(jnp.uint8) * iv.FLAG_IS
    # Map local witness slots to global ids.
    safe = lambda r: jnp.where(r >= 0, cand[jnp.clip(r, 0, C - 1)], -1)
    return status, safe(rep_if), safe(rep_is)


@functools.partial(
    jax.jit,
    static_argnames=("m_if", "m_is", "alpha", "unified"),
)
def unified_prune(
    u_ids: jnp.ndarray,     # (B,) int32 node ids of this block
    cand: jnp.ndarray,      # (B, C) int32 candidate ids, -1 padded
    x: jnp.ndarray,         # (n, d) corpus vectors
    intervals: jnp.ndarray, # (n, 2) corpus intervals
    *,
    m_if: int,
    m_is: int,
    alpha: float = 1.0,
    unified: bool = True,
) -> PruneResult:
    """Vectorized Alg. 3 over a block of ``B`` nodes.

    Returns neighbor sets in ascending-distance order together with the
    semantic bitmask of every surviving edge and the repair pairs ``(w, v)``
    feeding Alg. 2's next iteration.
    """
    B, C = cand.shape
    safe_cand = jnp.clip(cand, 0, x.shape[0] - 1)
    xu = x[u_ids]                                # (B, d)
    xc = x[safe_cand]                            # (B, C, d)
    d_uc = squared_dist(xu[:, None, :], xc)[:, 0, :]       # (B, C)
    # Exclude self-edges and padding before sorting.
    d_uc = jnp.where((cand < 0) | (cand == u_ids[:, None]), jnp.inf, d_uc)
    cand_sorted, d_sorted = jax.vmap(_dedup_sorted_by_distance)(cand, d_uc)

    safe_sorted = jnp.clip(cand_sorted, 0, x.shape[0] - 1)
    xs = x[safe_sorted]                          # (B, C, d)
    d_cc = squared_dist(xs, xs)                  # (B, C, C)
    i_c = intervals[safe_sorted]                 # (B, C, 2)
    i_u = intervals[u_ids]                       # (B, 2)

    status, rep_if, rep_is = jax.vmap(
        lambda a, b, c, dd, e: _prune_one_node(
            a, b, c, dd, e, m_if=m_if, m_is=m_is, alpha=alpha, unified=unified
        )
    )(i_u, cand_sorted, d_sorted, d_cc, i_c)

    return PruneResult(cand_sorted, d_sorted, status, rep_if, rep_is)
