"""Unified interval-aware pruning (paper Alg. 3 / Def. 3.1).

The single routine :func:`unified_prune` implements the paper's
``UnifiedPrune`` for a *block* of nodes at once.  It is the workhorse of both

* the **exact URNG reference** (candidate set = all other nodes, ``M = n``,
  which is precisely Def. 3.1 evaluated in per-node ascending-distance order),
* the **practical UG build** (bounded candidate pools from Alg. 1 + repair
  sets from Alg. 2).

This module owns the fixed-shape *preprocessing* — dedup, distance sort,
vector/interval gathers — and hands the scan itself to
``ops.prune_sweep`` (kernels/prune_sweep.py), which dispatches between the
fused Pallas kernel, its bit-identical plain-XLA twin, and the legacy
materialize-everything baseline (DESIGN.md §9).  Classical RNG pruning
(used by the post-filtering baseline) is the same routine with the semantic
witness conditions forced to ``True`` (``unified=False``).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import intervals as iv
from repro.kernels import ops


class PruneResult(NamedTuple):
    """Per-node pruning output, aligned to distance-sorted candidate order."""

    order: jnp.ndarray      # (B, C) int32 candidate ids sorted by δ(u, ·); -1 pad
    dist: jnp.ndarray       # (B, C) f32 squared distance to u (+inf for pads)
    status: jnp.ndarray     # (B, C) uint8 semantic bitmask (0 = fully pruned)
    repair_if: jnp.ndarray  # (B, C) int32 global id of the IF witness or -1
    repair_is: jnp.ndarray  # (B, C) int32 global id of the IS witness or -1


def squared_dist(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Blocked ‖a−b‖² via the matmul identity; fp32 accumulation on the MXU."""
    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    an = jnp.sum(a32 * a32, axis=-1)
    bn = jnp.sum(b32 * b32, axis=-1)
    ip = jnp.einsum("...id,...jd->...ij", a32, b32, preferred_element_type=jnp.float32)
    d = an[..., :, None] + bn[..., None, :] - 2.0 * ip
    return jnp.maximum(d, 0.0)


def _dedup_sorted_by_distance(cand: jnp.ndarray, dist: jnp.ndarray):
    """Mask duplicate candidate ids (keep the closest copy), then sort by
    distance.

    ``cand`` is (C,) int32 with -1 padding; ``dist`` is (C,) f32.  Among
    copies of the same id the minimum-distance one survives (ties broken by
    scan position); masked copies and -1 pads sort to the back as +inf.
    """
    big = jnp.float32(jnp.inf)
    invalid = cand < 0
    dist = jnp.where(invalid, big, dist)
    # Detect duplicates by sorting (id, dist) lexicographically and flagging
    # repeats: the first copy in that order is the closest one.
    id_order = jnp.lexsort((dist, cand))
    sorted_ids = cand[id_order]
    dup_sorted = jnp.concatenate(
        [jnp.zeros((1,), bool), sorted_ids[1:] == sorted_ids[:-1]]
    ) & (sorted_ids >= 0)
    dup = jnp.zeros_like(dup_sorted).at[id_order].set(dup_sorted)
    dist = jnp.where(dup, big, dist)
    order = jnp.argsort(dist)
    out_d = dist[order]
    # Dead slots (pads, masked duplicates) are normalized to -1 so junk ids
    # can never leak into neighbor lists downstream.
    out_c = jnp.where(jnp.isfinite(out_d), cand[order], -1)
    return out_c, out_d


@functools.partial(
    jax.jit,
    static_argnames=("m_if", "m_is", "alpha", "unified", "backend"),
)
def unified_prune(
    u_ids: jnp.ndarray,     # (B,) int32 node ids of this block
    cand: jnp.ndarray,      # (B, C) int32 candidate ids, -1 padded
    x: jnp.ndarray,         # (n, d) corpus vectors
    intervals: jnp.ndarray, # (n, 2) corpus intervals
    *,
    m_if: int,
    m_is: int,
    alpha: float = 1.0,
    unified: bool = True,
    backend: str | None = None,
) -> PruneResult:
    """Vectorized Alg. 3 over a block of ``B`` nodes.

    Returns neighbor sets in ascending-distance order together with the
    semantic bitmask of every surviving edge and the repair pairs ``(w, v)``
    feeding Alg. 2's next iteration.  ``backend`` selects the sweep
    implementation (``pallas`` / ``xla`` / ``legacy``, default per platform);
    all three are bit-identical.
    """
    B, C = cand.shape
    safe_cand = jnp.clip(cand, 0, x.shape[0] - 1)
    xu = x[u_ids]                                # (B, d)
    xc = x[safe_cand]                            # (B, C, d)
    d_uc = squared_dist(xu[:, None, :], xc)[:, 0, :]       # (B, C)
    # Exclude self-edges and padding before sorting.
    d_uc = jnp.where((cand < 0) | (cand == u_ids[:, None]), jnp.inf, d_uc)
    cand_sorted, d_sorted = jax.vmap(_dedup_sorted_by_distance)(cand, d_uc)

    safe_sorted = jnp.clip(cand_sorted, 0, x.shape[0] - 1)
    xs = x[safe_sorted].astype(jnp.float32)      # (B, C, d)
    i_c = intervals[safe_sorted]                 # (B, C, 2)
    i_u = intervals[u_ids]                       # (B, 2)

    valid = (cand_sorted >= 0) & jnp.isfinite(d_sorted)
    if unified:
        overlap = ~iv.is_empty(iv.intersection(i_u[:, None, :], i_c))
    else:
        overlap = jnp.ones((B, C), bool)

    status, rep_if, rep_is = ops.prune_sweep(
        i_u, xs, i_c, d_sorted, valid, overlap,
        m_if=m_if, m_is=m_is, alpha=alpha, unified=unified, backend=backend,
    )

    # Map local witness slots to global candidate ids.
    def to_global(rep):
        g = jnp.take_along_axis(cand_sorted, jnp.clip(rep, 0, C - 1), axis=-1)
        return jnp.where(rep >= 0, g, -1)

    return PruneResult(
        cand_sorted, d_sorted, status.astype(jnp.uint8),
        to_global(rep_if), to_global(rep_is),
    )
