"""Baselines from the paper's experimental section (§2.2, §5.1).

* ``PostFilterIndex``   — interval-agnostic RNG-style graph (HNSW/NSG/Vamana
  family stand-in: same candidate + prune pipeline with the semantic witness
  conditions disabled, optional Vamana α); search retrieves an oversampled
  top-k′ by pure similarity, then discards predicate violators.
* ``prefilter_search``  — materialize the valid subset, exact scan over it
  (the pre-filtering strategy; exact, pays O(n) per query).
* ``HiPNGLite``         — hierarchical interval partition (Hi-PNG [57] style):
  a segment tree over the attribute domain, one graph per tree node, objects
  assigned to the lowest node containing their interval; IF queries search
  the O(log) canonical cover of q.I, post-checking the predicate.
* ``RRNG``              — the scalar special case (paper §3.2 末): point
  object intervals + IF projection only == RFANN-dedicated index.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import intervals as iv
from repro.core.build import UGConfig, build_ug
from repro.core.entry import build_entry_index, get_entry
from repro.core.exact import DenseGraph
from repro.core.search import SearchResult, beam_search, brute_force
from repro.core.store import make_store
from repro.core.candidates import merge_topk


# --------------------------------------------------------------------------
# Post-filtering over an interval-agnostic graph
# --------------------------------------------------------------------------
@dataclasses.dataclass
class PostFilterIndex:
    """Interval-agnostic proximity graph + oversample-then-filter search."""

    x: jnp.ndarray
    intervals: jnp.ndarray
    graph: DenseGraph
    build_seconds: float = 0.0

    @classmethod
    def build(cls, x, intervals, config: UGConfig = UGConfig(), seed: int = 0):
        x = jnp.asarray(x)
        intervals = jnp.asarray(intervals)
        cfg = dataclasses.replace(config, unified=False)
        t0 = time.perf_counter()
        graph = build_ug(jax.random.key(seed), x, intervals, cfg)
        jax.block_until_ready(graph.nbrs)
        return cls(x, intervals, graph, time.perf_counter() - t0)

    def search(
        self, q_v, q_int, *, sem: iv.Semantics, ef: int = 64, k: int = 10,
        oversample: int = 4, max_steps: int = 0,
    ) -> SearchResult:
        """Similarity-only beam search for k′ = oversample·k, then filter."""
        n = self.x.shape[0]
        q_v = jnp.asarray(q_v)
        q_int = jnp.asarray(q_int)
        # Unconstrained search: every edge passes, every node matches.
        free_int = jnp.broadcast_to(
            jnp.asarray([[-jnp.inf, jnp.inf]], jnp.float32), q_int.shape
        )
        # Entry: node 0 (graph is connected enough; paper baselines use the
        # default HNSW entry point).
        entry_ids = jnp.zeros((q_v.shape[0],), jnp.int32)
        kprime = min(max(k * oversample, ef), ef)
        store = make_store(
            self.x, self.intervals, self.graph.nbrs, self.graph.status,
            build_entry=False,
        )
        res = beam_search(
            store, entry_ids, q_v, free_int,
            sem=iv.Semantics.IF, ef=ef, k=kprime, max_steps=max_steps,
        )
        ok = iv.predicate(
            sem,
            self.intervals[jnp.clip(res.ids, 0, n - 1)],
            q_int[:, None, :],
        ) & (res.ids >= 0)
        d = jnp.where(ok, res.dist, jnp.inf)
        order = jnp.argsort(d, axis=-1)[:, :k]
        ids = jnp.take_along_axis(res.ids, order, axis=-1)
        d = jnp.take_along_axis(d, order, axis=-1)
        ids = jnp.where(jnp.isfinite(d), ids, -1)
        return SearchResult(ids, d, res.steps)


# --------------------------------------------------------------------------
# Pre-filtering (exact scan over the valid subset)
# --------------------------------------------------------------------------
def prefilter_search(x, intervals, q_v, q_int, *, sem: iv.Semantics, k: int):
    """Pre-filtering strategy: exact, O(n·d) per query batch."""
    return brute_force(x, intervals, jnp.asarray(q_v), jnp.asarray(q_int), sem=sem, k=k)


# --------------------------------------------------------------------------
# Hi-PNG-lite: hierarchical interval partition of sub-graphs
# --------------------------------------------------------------------------
@dataclasses.dataclass
class _Partition:
    lo: float
    hi: float
    node_ids: np.ndarray           # global ids in this partition
    graph: DenseGraph | None       # local graph over the partition rows
    x: jnp.ndarray | None
    intervals: jnp.ndarray | None


@dataclasses.dataclass
class HiPNGLite:
    """Segment-tree of interval partitions, one sub-graph per tree node.

    Objects live at the lowest tree node whose range contains their interval.
    An IFANN query searches every tree node whose range intersects ``q.I``
    (their objects are the only possible matches), post-checking containment.
    """

    partitions: List[_Partition]
    depth: int
    build_seconds: float = 0.0

    @classmethod
    def build(
        cls, x, intervals, *, depth: int = 3, config: UGConfig = UGConfig(),
        seed: int = 0, domain=(0.0, 1.0),
    ):
        x_np = np.asarray(x)
        iv_np = np.asarray(intervals)
        n = x_np.shape[0]
        t0 = time.perf_counter()
        parts: List[_Partition] = []
        ranges = []
        for level in range(depth + 1):
            cells = 2 ** level
            width = (domain[1] - domain[0]) / cells
            for c in range(cells):
                ranges.append((domain[0] + c * width, domain[0] + (c + 1) * width, level))
        # Assign each object to the *deepest* covering range.
        assign = np.full((n,), -1, np.int64)
        best_level = np.full((n,), -1, np.int64)
        for pid, (lo, hi, level) in enumerate(ranges):
            covered = (iv_np[:, 0] >= lo) & (iv_np[:, 1] <= hi + 1e-12)
            upgrade = covered & (level > best_level)
            assign[upgrade] = pid
            best_level[upgrade] = level
        cfg = dataclasses.replace(config, unified=False)
        for pid, (lo, hi, level) in enumerate(ranges):
            rows = np.nonzero(assign == pid)[0].astype(np.int32)
            if rows.size == 0:
                parts.append(_Partition(lo, hi, rows, None, None, None))
                continue
            xs = jnp.asarray(x_np[rows])
            ivs = jnp.asarray(iv_np[rows])
            if rows.size <= 8:
                graph = DenseGraph(
                    jnp.broadcast_to(
                        jnp.arange(rows.size, dtype=jnp.int32)[None, :], (rows.size, rows.size)
                    ),
                    jnp.full((rows.size, rows.size), iv.FLAG_BOTH, jnp.uint8),
                )
            else:
                local_cfg = dataclasses.replace(
                    cfg,
                    ef_spatial=min(cfg.ef_spatial, max(rows.size - 1, 1)),
                    ef_attribute=min(cfg.ef_attribute, max(rows.size - 1, 1)),
                    exact_spatial=rows.size <= 2048,
                )
                graph = build_ug(jax.random.key(seed + pid), xs, ivs, local_cfg)
            parts.append(_Partition(lo, hi, rows, graph, xs, ivs))
        obj = cls(parts, depth, time.perf_counter() - t0)
        return obj

    def search(self, q_v, q_int, *, ef: int = 64, k: int = 10) -> SearchResult:
        """IFANN search across intersecting partitions, merged per query."""
        q_v = jnp.asarray(q_v)
        q_int_np = np.asarray(q_int)
        nq = q_v.shape[0]
        best_ids = jnp.full((nq, k), -1, jnp.int32)
        best_d = jnp.full((nq, k), jnp.inf, jnp.float32)
        total_steps = jnp.zeros((nq,), jnp.int32)
        for part in self.partitions:
            if part.graph is None or part.node_ids.size == 0:
                continue
            lo, hi = part.lo, part.hi
            touches = (q_int_np[:, 0] <= hi) & (q_int_np[:, 1] >= lo)
            if not touches.any():
                continue
            # Search the whole batch (mask away non-touching queries).
            free_int = jnp.broadcast_to(
                jnp.asarray([[-jnp.inf, jnp.inf]], jnp.float32), (nq, 2)
            )
            entry = jnp.where(jnp.asarray(touches), 0, -1).astype(jnp.int32)
            kk = min(4 * k, max(part.node_ids.size, 1), ef)
            store = make_store(
                part.x, part.intervals, part.graph.nbrs, part.graph.status,
                build_entry=False,
            )
            res = beam_search(
                store, entry, q_v, free_int,
                sem=iv.Semantics.IF, ef=ef, k=kk,
            )
            nloc = part.x.shape[0]
            ok = iv.predicate(
                iv.Semantics.IF,
                part.intervals[jnp.clip(res.ids, 0, nloc - 1)],
                jnp.asarray(q_int)[:, None, :],
            ) & (res.ids >= 0)
            d = jnp.where(ok, res.dist, jnp.inf)
            gids = jnp.asarray(part.node_ids)[jnp.clip(res.ids, 0, nloc - 1)]
            gids = jnp.where(jnp.isfinite(d), gids, -1)
            best_ids, best_d = merge_topk(best_ids, best_d, gids, d, k)
            total_steps = total_steps + res.steps
        return SearchResult(best_ids, best_d, total_steps)


# --------------------------------------------------------------------------
# RRNG — the scalar / RFANN special case (URNG with point intervals, IF only)
# --------------------------------------------------------------------------
def build_rrng(key, x, scalars, config: UGConfig = UGConfig()) -> DenseGraph:
    """RRNG [64] as the degenerate URNG (paper §3.2): I_o = [a, a], IF bit."""
    a = jnp.asarray(scalars).reshape(-1, 1)
    point_intervals = jnp.concatenate([a, a], axis=1)
    return build_ug(key, jnp.asarray(x), point_intervals, config)
