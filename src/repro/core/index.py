"""UGIndex — the user-facing unified interval-aware index (paper §4).

One physical graph + per-edge semantic bitmask answers IFANN / ISANN /
RFANN / RSANN queries (paper §2.1).  RF datasets store scalars as point
intervals; RS queries pass point query intervals — both reductions are
exact (§2.1).

Since DESIGN.md §12 the index is a thin host-side handle around one
:class:`~repro.core.store.IndexStore` pytree — the store is what every
layer (search, updates, serving, sharding, checkpointing) shares, and it
is held *by reference* everywhere (attaching an index to a ServeEngine
copies nothing).  The legacy array views (``x``/``intervals``/``graph``/
``entry``/``alive``/``free``) are properties over the store's buffers.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import intervals as iv
from repro.core.build import UGConfig, build_ug
from repro.core.entry import EntryIndex, build_entry_index
from repro.core.exact import DenseGraph
from repro.core.search import SearchResult, brute_force
from repro.core.search import search as core_search
from repro.core.search import search_mixed as core_search_mixed
from repro.core.store import IndexStore, VectorPlane, make_store


@dataclasses.dataclass
class UGIndex:
    """Unified graph index: one :class:`IndexStore` + build config.

    Store arrays are sized to ``capacity`` slots; ``alive`` marks the live
    nodes and ``free`` the slots the streaming allocator may hand out again
    (DESIGN.md §11).  A freshly built or loaded static index leaves both
    ``None`` (all slots live, none free) and pays zero masking cost.
    """

    store: IndexStore
    config: UGConfig
    build_seconds: float = 0.0

    # --------------------------------------------------------- store views
    @property
    def x(self) -> jnp.ndarray:
        """f32 view of the vectors: the exact rerank plane when present,
        else the decoded scan plane (identity — same buffer — for f32)."""
        return self.store.vectors_f32()

    @property
    def intervals(self) -> jnp.ndarray:
        return self.store.intervals

    @property
    def graph(self) -> DenseGraph:
        return self.store.graph

    @property
    def entry(self) -> EntryIndex:
        return self.store.entry

    @property
    def alive(self) -> jnp.ndarray | None:
        return self.store.alive

    @property
    def free(self) -> jnp.ndarray | None:
        return self.store.free

    @property
    def dtype(self) -> str:
        """Scan-plane tag: ``f32`` | ``bf16`` | ``int8`` | ``pq``."""
        return self.store.plane.tag

    def with_store(self, store: IndexStore) -> "UGIndex":
        return dataclasses.replace(self, store=store)

    # ------------------------------------------------------------------ build
    @classmethod
    def build(
        cls,
        x,
        intervals,
        config: UGConfig = UGConfig(),
        seed: int = 0,
        progress=None,
        *,
        dtype: str = "f32",
        rerank: bool | None = None,
    ) -> "UGIndex":
        """Alg. 1–3 build + plane encoding.

        The graph is always constructed from the f32 vectors; ``dtype``
        selects the *scan plane* the serving path scores against, and
        ``rerank`` attaches the exact f32 plane for final-top-k re-scoring
        (default: on for ``int8``/``pq``, off otherwise)."""
        x = jnp.asarray(x)
        intervals = jnp.asarray(intervals)
        t0 = time.perf_counter()
        graph = build_ug(jax.random.key(seed), x, intervals, config, progress)
        jax.block_until_ready(graph.nbrs)
        dt = time.perf_counter() - t0
        if rerank is None:
            rerank = dtype in ("int8", "pq")
        store = make_store(
            x, intervals, graph.nbrs, graph.status, dtype=dtype, rerank=rerank,
        )
        return cls(store, config, dt)

    def with_dtype(self, dtype: str, *, rerank: bool | None = None) -> "UGIndex":
        """Re-encode the vector planes (same graph, same ids): the
        cross-dtype parity harness — search quality of a ``bf16``/``int8``
        plane is measured against the f32 plane *on the identical graph*."""
        if rerank is None:
            rerank = dtype in ("int8", "pq")
        x = self.store.vectors_f32()
        store = self.store.replace(
            plane=VectorPlane.encode(x, dtype),
            rerank=VectorPlane.encode(x, "f32") if rerank else None,
        )
        return self.with_store(store)

    # ----------------------------------------------------------------- search
    def search(
        self,
        q_v,
        q_int,
        *,
        sem: iv.Semantics = iv.Semantics.IF,
        ef: int = 64,
        k: int = 10,
        max_steps: int = 0,
        backend: str | None = None,
        width: int = 4,
    ) -> SearchResult:
        """Alg. 5 + Alg. 4.  ``backend``/``width`` select the search pipeline
        (fused multi-expansion by default; see core/search.py)."""
        return core_search(
            self.store, jnp.asarray(q_v), jnp.asarray(q_int),
            sem=sem, ef=ef, k=k, max_steps=max_steps,
            backend=backend, width=width,
        )

    def search_mixed(
        self,
        q_v,
        q_int,
        sem_flags,
        *,
        ef: int = 64,
        k: int = 10,
        max_steps: int = 0,
        backend: str | None = None,
        width: int = 4,
    ) -> SearchResult:
        """Alg. 5 + Alg. 4 for a batch whose queries each carry their own
        semantics — one compiled program serves interleaved IF/IS/RF/RS
        traffic (DESIGN.md §10).  ``sem_flags`` accepts a per-query sequence
        of :class:`Semantics`, a flag array, or a single ``Semantics``."""
        return core_search_mixed(
            self.store, jnp.asarray(q_v), jnp.asarray(q_int), sem_flags,
            ef=ef, k=k, max_steps=max_steps, backend=backend, width=width,
        )

    def ground_truth(self, q_v, q_int, *, sem: iv.Semantics, k: int) -> SearchResult:
        """Exact predicate-filtered top-k over the best-precision vectors
        (the rerank plane when present, else the decoded scan plane)."""
        return brute_force(
            self.store.vectors_f32(), self.intervals,
            jnp.asarray(q_v), jnp.asarray(q_int),
            sem=sem, k=k, alive=self.alive,
        )

    # ---------------------------------------------------------------- updates
    def insert(self, new_x, new_intervals, **kw) -> "UGIndex":
        """Batched streaming insert (DESIGN.md §11); returns a new UGIndex."""
        from repro.core.updates import insert_batch

        return insert_batch(self, new_x, new_intervals, **kw)

    def delete(self, ids, **kw) -> "UGIndex":
        """Batched tombstone delete + iterative repair; returns a new UGIndex."""
        from repro.core.updates import delete_batch

        return delete_batch(self, ids, **kw)

    def compact(self) -> "UGIndex":
        """Physically drop dead slots and remap the graph (DESIGN.md §11)."""
        from repro.core.updates import compact

        return compact(self)

    # ------------------------------------------------------------------ stats
    @property
    def capacity(self) -> int:
        """Allocated slots (live + tombstoned + free)."""
        return self.store.capacity

    @property
    def n(self) -> int:
        """Live node count (== capacity for a static index)."""
        if self.alive is None:
            return self.store.capacity
        return int(jnp.sum(self.alive))

    def memory_bytes(self) -> int:
        """Graph + entry + allocator bytes (the index *overhead* the paper's
        memory tables report; vector planes via :meth:`vector_memory_bytes`)."""
        m = self.store.memory_bytes()
        return int(m["graph"] + m["entry"] + m["masks"])

    def vector_memory_bytes(self) -> dict:
        """Per-plane vector bytes (scan plane, rerank plane, per-vector).

        Bytes/vec amortizes over the *live* count, not capacity — after
        ``grow()`` doubles capacity the figure must not silently halve."""
        m = self.store.memory_bytes()
        return {
            "plane": m["plane"],
            "rerank": m["rerank"],
            "plane_bytes_per_vector": self.store.plane.bytes_per_vector(self.n),
        }

    def degree_stats(self) -> dict:
        g = self.graph
        d_if = np.asarray(g.degree(iv.FLAG_IF))
        d_is = np.asarray(g.degree(iv.FLAG_IS))
        if self.alive is not None:  # stats over live rows only
            live = np.asarray(self.alive)
            d_if = d_if[live]
            d_is = d_is[live]
        return {
            "mean_if": float(d_if.mean()),
            "mean_is": float(d_is.mean()),
            "max_if": int(d_if.max()),
            "max_is": int(d_is.max()),
            "edges": int((np.asarray(g.nbrs) >= 0).sum()),
        }

    # ------------------------------------------------------------------- io
    def save(self, path: str | pathlib.Path) -> None:
        path = pathlib.Path(path)
        path.mkdir(parents=True, exist_ok=True)
        st = self.store
        x_np = np.asarray(st.plane.data)
        if st.plane.tag == "bf16":
            # numpy serializes ml_dtypes bfloat16 as raw void ('|V2') and
            # cannot read it back: store the codes as a uint16 bit view
            # (load re-casts keyed on the saved dtype tag).
            x_np = x_np.view(np.uint16)
        arrays = dict(
            x=x_np,
            intervals=np.asarray(st.intervals),
            nbrs=np.asarray(st.nbrs),
            status=np.asarray(st.status),
        )
        if st.plane.scale is not None:
            arrays["x_scale"] = np.asarray(st.plane.scale)
            arrays["x_zero"] = np.asarray(st.plane.zero)
        if st.plane.codebooks is not None:
            arrays["x_codebooks"] = np.asarray(st.plane.codebooks)
        if st.rerank is not None:
            arrays["rerank"] = np.asarray(st.rerank.data)
        if st.alive is not None:
            arrays["alive"] = np.asarray(st.alive)
            arrays["free"] = (
                np.zeros(arrays["alive"].shape, bool) if st.free is None
                else np.asarray(st.free)
            )
        np.savez_compressed(path / "index.npz", **arrays)
        meta = dataclasses.asdict(self.config)
        meta["build_seconds"] = self.build_seconds
        meta["dtype"] = st.plane.tag
        (path / "meta.json").write_text(json.dumps(meta, indent=2))

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "UGIndex":
        path = pathlib.Path(path)
        blob = np.load(path / "index.npz")
        meta = json.loads((path / "meta.json").read_text())
        build_seconds = meta.pop("build_seconds", 0.0)
        tag = meta.pop("dtype", "f32")
        cfg = UGConfig(**meta)
        intervals = jnp.asarray(blob["intervals"])
        alive = jnp.asarray(blob["alive"]) if "alive" in blob.files else None
        free = jnp.asarray(blob["free"]) if "free" in blob.files else None
        x_np = blob["x"]
        if tag == "bf16":  # stored as a uint16 bit view (see save)
            x_np = jnp.asarray(x_np).view(jnp.bfloat16)
        plane = VectorPlane(
            tag, jnp.asarray(x_np),
            jnp.asarray(blob["x_scale"]) if "x_scale" in blob.files else None,
            jnp.asarray(blob["x_zero"]) if "x_zero" in blob.files else None,
            jnp.asarray(blob["x_codebooks"])
            if "x_codebooks" in blob.files else None,
        )
        rerank = (
            VectorPlane("f32", jnp.asarray(blob["rerank"]))
            if "rerank" in blob.files else None
        )
        store = IndexStore(
            plane=plane, rerank=rerank, intervals=intervals,
            nbrs=jnp.asarray(blob["nbrs"]), status=jnp.asarray(blob["status"]),
            entry=build_entry_index(intervals, node_mask=alive),
            alive=alive, free=free,
        )
        return cls(store, cfg, build_seconds)


def recall(result: SearchResult, truth: SearchResult) -> float:
    """recall@k as in the paper §5.1 (set overlap with brute-force truth)."""
    r = np.asarray(result.ids)
    t = np.asarray(truth.ids)
    hits = 0
    denom = 0
    for i in range(r.shape[0]):
        tset = set(int(v) for v in t[i] if v >= 0)
        if not tset:
            continue
        rset = set(int(v) for v in r[i] if v >= 0)
        hits += len(tset & rset)
        denom += len(tset)
    return hits / max(denom, 1)
