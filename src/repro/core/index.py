"""UGIndex — the user-facing unified interval-aware index (paper §4).

One physical graph + per-edge semantic bitmask answers IFANN / ISANN / RFANN /
RSANN queries (paper §2.1).  RF datasets store scalars as point intervals;
RS queries pass point query intervals — both reductions are exact (§2.1).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import intervals as iv
from repro.core.build import UGConfig, build_ug
from repro.core.entry import EntryIndex, build_entry_index, get_entry
from repro.core.exact import DenseGraph
from repro.core.search import SearchResult, beam_search, brute_force
from repro.core.search import search as core_search
from repro.core.search import search_mixed as core_search_mixed


@dataclasses.dataclass
class UGIndex:
    """Unified graph index: corpus, intervals, graph, entry structure.

    Arrays are sized to ``capacity`` slots; ``alive`` marks the live nodes
    and ``free`` the slots the streaming allocator may hand out again
    (DESIGN.md §11).  A freshly built or loaded static index leaves both
    ``None`` (all slots live, none free) and pays zero masking cost.
    """

    x: jnp.ndarray            # (cap, d)
    intervals: jnp.ndarray    # (cap, 2)
    graph: DenseGraph
    entry: EntryIndex
    config: UGConfig
    build_seconds: float = 0.0
    alive: jnp.ndarray | None = None   # (cap,) bool; None = all live
    free: jnp.ndarray | None = None    # (cap,) bool; None = none free

    # ------------------------------------------------------------------ build
    @classmethod
    def build(
        cls,
        x,
        intervals,
        config: UGConfig = UGConfig(),
        seed: int = 0,
        progress=None,
    ) -> "UGIndex":
        x = jnp.asarray(x)
        intervals = jnp.asarray(intervals)
        t0 = time.perf_counter()
        graph = build_ug(jax.random.key(seed), x, intervals, config, progress)
        eidx = build_entry_index(intervals)
        jax.block_until_ready(graph.nbrs)
        dt = time.perf_counter() - t0
        return cls(x, intervals, graph, eidx, config, dt)

    # ----------------------------------------------------------------- search
    def search(
        self,
        q_v,
        q_int,
        *,
        sem: iv.Semantics = iv.Semantics.IF,
        ef: int = 64,
        k: int = 10,
        max_steps: int = 0,
        backend: str | None = None,
        width: int = 4,
    ) -> SearchResult:
        """Alg. 5 + Alg. 4.  ``backend``/``width`` select the search pipeline
        (fused multi-expansion by default; see core/search.py)."""
        return core_search(
            self.x, self.intervals, self.graph.nbrs, self.graph.status,
            self.entry, jnp.asarray(q_v), jnp.asarray(q_int),
            sem=sem, ef=ef, k=k, max_steps=max_steps,
            backend=backend, width=width, alive=self.alive,
        )

    def search_mixed(
        self,
        q_v,
        q_int,
        sem_flags,
        *,
        ef: int = 64,
        k: int = 10,
        max_steps: int = 0,
        backend: str | None = None,
        width: int = 4,
    ) -> SearchResult:
        """Alg. 5 + Alg. 4 for a batch whose queries each carry their own
        semantics — one compiled program serves interleaved IF/IS/RF/RS
        traffic (DESIGN.md §10).  ``sem_flags`` accepts a per-query sequence
        of :class:`Semantics`, a flag array, or a single ``Semantics``."""
        return core_search_mixed(
            self.x, self.intervals, self.graph.nbrs, self.graph.status,
            self.entry, jnp.asarray(q_v), jnp.asarray(q_int), sem_flags,
            ef=ef, k=k, max_steps=max_steps, backend=backend, width=width,
            alive=self.alive,
        )

    def ground_truth(self, q_v, q_int, *, sem: iv.Semantics, k: int) -> SearchResult:
        return brute_force(
            self.x, self.intervals, jnp.asarray(q_v), jnp.asarray(q_int),
            sem=sem, k=k, alive=self.alive,
        )

    # ---------------------------------------------------------------- updates
    def insert(self, new_x, new_intervals, **kw) -> "UGIndex":
        """Batched streaming insert (DESIGN.md §11); returns a new UGIndex."""
        from repro.core.updates import insert_batch

        return insert_batch(self, new_x, new_intervals, **kw)

    def delete(self, ids, **kw) -> "UGIndex":
        """Batched tombstone delete + iterative repair; returns a new UGIndex."""
        from repro.core.updates import delete_batch

        return delete_batch(self, ids, **kw)

    def compact(self) -> "UGIndex":
        """Physically drop dead slots and remap the graph (DESIGN.md §11)."""
        from repro.core.updates import compact

        return compact(self)

    # ------------------------------------------------------------------ stats
    @property
    def capacity(self) -> int:
        """Allocated slots (live + tombstoned + free)."""
        return self.x.shape[0]

    @property
    def n(self) -> int:
        """Live node count (== capacity for a static index)."""
        if self.alive is None:
            return self.x.shape[0]
        return int(jnp.sum(self.alive))

    def memory_bytes(self) -> int:
        g = self.graph
        masks = 0 if self.alive is None else 2 * self.x.shape[0]
        return int(
            g.nbrs.size * g.nbrs.dtype.itemsize
            + g.status.size * g.status.dtype.itemsize
            + self.entry.l_sorted.size * 4 * 6
            + masks
        )

    def degree_stats(self) -> dict:
        g = self.graph
        d_if = np.asarray(g.degree(iv.FLAG_IF))
        d_is = np.asarray(g.degree(iv.FLAG_IS))
        if self.alive is not None:  # stats over live rows only
            live = np.asarray(self.alive)
            d_if = d_if[live]
            d_is = d_is[live]
        return {
            "mean_if": float(d_if.mean()),
            "mean_is": float(d_is.mean()),
            "max_if": int(d_if.max()),
            "max_is": int(d_is.max()),
            "edges": int((np.asarray(g.nbrs) >= 0).sum()),
        }

    # ------------------------------------------------------------------- io
    def save(self, path: str | pathlib.Path) -> None:
        path = pathlib.Path(path)
        path.mkdir(parents=True, exist_ok=True)
        arrays = dict(
            x=np.asarray(self.x),
            intervals=np.asarray(self.intervals),
            nbrs=np.asarray(self.graph.nbrs),
            status=np.asarray(self.graph.status),
        )
        if self.alive is not None:
            arrays["alive"] = np.asarray(self.alive)
            arrays["free"] = (
                np.zeros(arrays["alive"].shape, bool) if self.free is None
                else np.asarray(self.free)
            )
        np.savez_compressed(path / "index.npz", **arrays)
        meta = dataclasses.asdict(self.config)
        meta["build_seconds"] = self.build_seconds
        (path / "meta.json").write_text(json.dumps(meta, indent=2))

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "UGIndex":
        path = pathlib.Path(path)
        blob = np.load(path / "index.npz")
        meta = json.loads((path / "meta.json").read_text())
        build_seconds = meta.pop("build_seconds", 0.0)
        cfg = UGConfig(**meta)
        x = jnp.asarray(blob["x"])
        intervals = jnp.asarray(blob["intervals"])
        graph = DenseGraph(jnp.asarray(blob["nbrs"]), jnp.asarray(blob["status"]))
        alive = jnp.asarray(blob["alive"]) if "alive" in blob.files else None
        free = jnp.asarray(blob["free"]) if "free" in blob.files else None
        entry = build_entry_index(intervals, node_mask=alive)
        return cls(x, intervals, graph, entry, cfg, build_seconds, alive, free)


def recall(result: SearchResult, truth: SearchResult) -> float:
    """recall@k as in the paper §5.1 (set overlap with brute-force truth)."""
    r = np.asarray(result.ids)
    t = np.asarray(truth.ids)
    hits = 0
    denom = 0
    for i in range(r.shape[0]):
        tset = set(int(v) for v in t[i] if v >= 0)
        if not tset:
            continue
        rset = set(int(v) for v in r[i] if v >= 0)
        hits += len(tset & rset)
        denom += len(tset)
    return hits / max(denom, 1)
