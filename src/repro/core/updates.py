"""Incremental index maintenance: insert new objects into a built UG.

The paper's Hi-PNG-style partitioned baselines "complicate updates and
maintenance" (§2.3); the unified graph makes insertion local: a new object
needs (1) candidates — its spatial KNN within the existing corpus plus
interval-order neighbors, exactly Alg. 1 restricted to one row; (2) one
``UnifiedPrune`` pass for its own out-edges; (3) reverse-edge offers — the
new node is appended into *free slots* of its neighbors' lists under the
per-semantics degree budgets, leaving every existing edge untouched.

Step (3) deliberately does NOT re-prune the touched nodes: a fresh
``UnifiedPrune`` over (current neighbors ∪ new) forgets the repair edges
Alg. 2 added during the full build and measurably degrades old-query recall
(IS recall dropped ~0.3 when we re-pruned wholesale).  Appending is always
*sound* — search masks every traversed edge by the target's own semantic
bit and predicate, so extra edges can only add connectivity; witness
pruning is a degree optimization, not a correctness condition.  The IS bit
is only set when ``I_u ∩ I_new ≠ ∅`` (Alg. 3 lines 7-8).

Entry arrays are rebuilt lazily (O(n log n), amortized over a batch of
inserts).  This matches the paper's forward-looking maintenance story
without a full rebuild.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import intervals as ivm
from repro.core.build import UGConfig
from repro.core.candidates import merge_topk
from repro.core.entry import build_entry_index
from repro.core.exact import DenseGraph
from repro.core.index import UGIndex
from repro.core.prune import squared_dist, unified_prune


def insert(index: UGIndex, new_x, new_intervals) -> UGIndex:
    """Insert a batch of objects; returns a new UGIndex (functional update)."""
    new_x = jnp.atleast_2d(jnp.asarray(new_x))
    new_intervals = jnp.atleast_2d(jnp.asarray(new_intervals))
    b = new_x.shape[0]
    n_old = index.n
    cfg = index.config

    x_all = jnp.concatenate([index.x, new_x])
    iv_all = jnp.concatenate([index.intervals, new_intervals])
    new_ids = jnp.arange(n_old, n_old + b, dtype=jnp.int32)

    # ---- (1) candidates: spatial KNN over the old corpus + the four
    # interval-derived sort orders of Alg. 1 ({l, r, mid, len})
    d = squared_dist(new_x, index.x)                      # (b, n_old)
    k_spa = min(cfg.ef_spatial, n_old)
    _, spa = jax.lax.top_k(-d, k_spa)                     # (b, k_spa)
    l_o, r_o = index.intervals[:, 0], index.intervals[:, 1]
    keys_old = [l_o, r_o, (l_o + r_o) * 0.5, r_o - l_o]
    l_n, r_n = new_intervals[:, 0], new_intervals[:, 1]
    keys_new = [l_n, r_n, (l_n + r_n) * 0.5, r_n - l_n]
    w = max(cfg.ef_attribute // 8, 1)
    offs = jnp.arange(-w, w + 1)
    attrs = []
    for k_old, k_new in zip(keys_old, keys_new):
        order = jnp.argsort(k_old)
        pos = jnp.searchsorted(k_old[order], k_new)
        attr_pos = jnp.clip(pos[:, None] + offs[None, :], 0, n_old - 1)
        attrs.append(order[attr_pos].astype(jnp.int32))
    cand = jnp.concatenate([spa.astype(jnp.int32)] + attrs, axis=1)

    # ---- (2) prune the new nodes' out-edges
    res = unified_prune(
        new_ids, cand, x_all, iv_all,
        m_if=cfg.max_edges_if, m_is=cfg.max_edges_is,
        alpha=cfg.alpha, unified=cfg.unified, backend=cfg.prune_backend,
    )
    m_cols = index.graph.nbrs.shape[1]
    keep = min(m_cols, res.order.shape[1])
    score = jnp.where(res.status > 0, res.dist, jnp.inf)
    sel = jnp.argsort(score, axis=1)[:, :keep]
    new_nbrs = jnp.where(
        jnp.isfinite(jnp.take_along_axis(score, sel, axis=1)),
        jnp.take_along_axis(res.order, sel, axis=1), -1,
    )
    new_stat = jnp.where(
        new_nbrs >= 0, jnp.take_along_axis(res.status, sel, axis=1), 0
    )
    pad = m_cols - keep
    if pad:
        new_nbrs = jnp.pad(new_nbrs, ((0, 0), (0, pad)), constant_values=-1)
        new_stat = jnp.pad(new_stat, ((0, 0), (0, pad)))

    nbrs = jnp.concatenate([index.graph.nbrs, new_nbrs])
    stat = jnp.concatenate([index.graph.status, new_stat])

    # ---- (3) reverse offers: append u -> new into free slots under budgets
    nbrs_np = np.asarray(nbrs).copy()
    stat_np = np.asarray(stat).copy()
    iv_np = np.asarray(iv_all)
    new_nbrs_np = np.asarray(new_nbrs)
    for j in range(b):
        nid = n_old + j
        for v in new_nbrs_np[j]:
            if v < 0:
                continue
            u = int(v)
            row = nbrs_np[u]
            if nid in row:
                continue
            free = np.flatnonzero(row < 0)
            if free.size == 0:
                continue
            cnt_if = int(((stat_np[u] & ivm.FLAG_IF) > 0).sum())
            cnt_is = int(((stat_np[u] & ivm.FLAG_IS) > 0).sum())
            bits = 0
            if cnt_if < cfg.max_edges_if:
                bits |= ivm.FLAG_IF
            overlap = max(iv_np[u, 0], iv_np[nid, 0]) <= min(iv_np[u, 1], iv_np[nid, 1])
            if cnt_is < cfg.max_edges_is and overlap:
                bits |= ivm.FLAG_IS
            if bits == 0:
                continue
            nbrs_np[u, free[0]] = nid
            stat_np[u, free[0]] = bits
    nbrs = jnp.asarray(nbrs_np)
    stat = jnp.asarray(stat_np)

    graph = DenseGraph(nbrs, stat)
    return dataclasses.replace(
        index, x=x_all, intervals=iv_all, graph=graph,
        entry=build_entry_index(iv_all),
    )
