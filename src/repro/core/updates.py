"""Streaming index maintenance: batched insert / delete / repair / compact.

The paper builds the UG once (Alg. 1-3 + the Alg. 2 repair loop); a
production interval-aware service sees continuous churn — listings expire,
prices move, validity windows shift.  This module turns that lifecycle into
a jitted, batched subsystem (DESIGN.md §11):

* **slot allocator** — the :class:`~repro.core.store.IndexStore` arrays
  are sized to a power-of-two ``capacity``; ``alive`` marks live nodes,
  ``free`` the slots the allocator may hand out.  The allocator lives on
  the store (``masks``/``widen_rows``/``grow``, DESIGN.md §12); growth
  doubles capacity, so array shapes (and therefore compiled programs)
  change O(log n) times over any insert stream.  Vector planes ride
  along: new rows are encoded under each plane's frozen quantization
  parameters, pruning distances run over the best-precision f32 view
  (the rerank plane when present, else the decoded scan plane —
  identity for f32);
* **insert_batch** — one jitted program per (batch, capacity) shape:
  candidate acquisition via the *existing fused beam search* (spatial) +
  the Alg. 1 interval sort orders (attribute), ``UnifiedPrune`` for the new
  nodes' out-edges through ``ops.prune_sweep``, and reverse-edge offers
  appended under the per-semantics degree budgets as one sequential
  ``lax.scan`` over the batch (within a step the offer targets are
  distinct, so each step is one conflict-free scatter);
* **delete_batch** — tombstone the nodes (``alive=False``): search routes
  *through* them but never surfaces them (the mask threads through
  ``beam_search_flags`` result extraction and the entry structure's
  ``node_mask``).  With ``repair=True`` the iterative-repair sweep then
  re-wires every in-neighbor of a deleted node through that node's
  out-neighbors: bridge candidates (2-hop ids, scored one row at a time by
  ``ops.expand_score``, distance-truncated) run through the same Φ_IF/Φ_IS
  witness machinery (``ops.prune_sweep``) the build uses, and accepted
  bridges refill the freed degree budget — as a blocked ``lax.map`` over
  the touched rows only.  ``repair_iters > 1`` continues with Alg. 2
  rounds (witness repair sets via ``scatter_repairs``) restricted to the
  affected rows;
* **compact** — physically drops dead slots and remaps the graph.

Neither path ever re-prunes an existing edge (the PR-1 lesson: wholesale
re-pruning forgets the build's Alg. 2 repair edges and measurably degrades
old-query recall).  Inserts *append* reverse offers into free slots;
repair keeps every surviving edge verbatim and witness-filters only the
*bridges* it appends.  Appending is always sound — search masks every
traversed edge by the target's semantic bit and predicate — so extra edges
only add connectivity, and the degree budgets stay enforced.

Memory discipline matches the build and search pipelines:
:func:`update_memory_profile` walks the traced insert and repair programs
and certifies that no quadratic ``(·, C, C)`` witness/dedup tensor and no
``(B, C, d)`` bridge/search gather is ever materialized — bridge
candidates are scored one row at a time by the expand-score kernel and the
witness scan runs through the fused prune sweep.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import intervals as ivm
from repro.core.build import UGConfig, scatter_repairs
from repro.core.entry import build_entry_index, get_entry_batch_flags
from repro.core.index import UGIndex
from repro.core.prune import unified_prune
from repro.core.search import beam_search_flags
from repro.kernels import ops
from repro.kernels.expand_score import dedup_first
from repro.kernels.util import pad_to

# Query window every finite interval satisfies under IF: candidate
# acquisition searches the IF projection with this window so the fused beam
# search behaves as an unconstrained spatial ANN over the live corpus.
_WIDE = 1e30

# The slot allocator itself lives on the store (DESIGN.md §12):
# ``IndexStore.masks`` materializes the lazy alive/free masks,
# ``IndexStore.widen_rows`` restores the update-time degree headroom, and
# ``IndexStore.grow`` doubles capacity.  The pipelines below consume a
# store whose masks are already materialized.


# ------------------------------------------------------------------- insert
@functools.partial(
    jax.jit,
    static_argnames=("cfg", "backend", "search_backend", "ef", "width"),
)
def _insert_core(
    store,                               # IndexStore (masks materialized)
    new_x, new_iv, valid,                # the batch; ``valid`` masks pad rows
    *,
    cfg: UGConfig,
    backend: str | None,
    search_backend: str | None,
    ef: int,
    width: int,
):
    """One jitted insert step over a ``b``-row batch (DESIGN.md §11).

    Pad rows (``valid=False``, from the serve-path shape buckets) flow
    through every stage with sentinel slot ``cap`` and are dropped by every
    scatter — a padded batch is bitwise equal to the unpadded one.

    Candidate acquisition searches the store's *scan plane* (so a quantized
    index acquires through the same kernels it serves with); pruning and
    reverse-offer distances run over the best-precision f32 view (the
    rerank plane when present, else the decoded scan plane — identity for
    f32).  New rows are encoded into every plane under its frozen
    quantization parameters.
    """
    x = store.vectors_f32()              # pruning-precision (cap, d) f32 view
    ivs, nbrs, status = store.intervals, store.nbrs, store.status
    alive, free = store.alive, store.free
    cap, d = x.shape
    b = new_x.shape[0]
    M = nbrs.shape[1]

    # ---- slot allocation: the j-th valid row takes the j-th free slot.
    free_slots, = jnp.nonzero(free, size=b, fill_value=cap)
    rank = jnp.cumsum(valid.astype(jnp.int32)) - 1
    slots = jnp.where(valid, free_slots[jnp.clip(rank, 0, b - 1)], cap)
    slot_c = jnp.clip(slots, 0, cap - 1)

    alive_old = alive                     # candidates = pre-insert live set
    new32 = new_x.astype(jnp.float32)
    x2 = x.at[slots].set(new32, mode="drop")
    iv2 = ivs.at[slots].set(new_iv.astype(ivs.dtype), mode="drop")
    alive2 = alive.at[slots].set(True, mode="drop")
    free2 = free.at[slots].set(False, mode="drop")

    # ---- plane updates: encode the new rows under each plane's frozen
    # parameters.  When the f32 scan plane IS the pruning view, its update
    # is exactly ``x2`` (no second scatter).
    if store.plane.tag == "f32" and store.rerank is None:
        plane2 = dataclasses.replace(store.plane, data=x2)
        rerank2 = None
    else:
        plane2 = dataclasses.replace(
            store.plane,
            data=store.plane.data.at[slots].set(
                store.plane.encode_rows(new32), mode="drop"),
        )
        rerank2 = None if store.rerank is None else dataclasses.replace(
            store.rerank, data=x2,
        )

    # ---- (1a) spatial candidates: fused beam search on the pre-insert
    # graph.  Two acquisition passes through ONE compiled program (runtime
    # semantics, DESIGN.md §10): the IF projection under a window every
    # live interval satisfies (unconstrained spatial ANN), and the IS
    # projection stabbed at the new interval's midpoint (spatially close
    # nodes that *overlap* the new node — prime IS-edge candidates).
    eidx_old = build_entry_index(ivs, node_mask=alive_old)
    wide = jnp.broadcast_to(jnp.asarray([-_WIDE, _WIDE], jnp.float32), (b, 2))
    mid = ((new_iv[:, 0] + new_iv[:, 1]) * 0.5).astype(jnp.float32)
    point = jnp.stack([mid, mid], axis=1)
    k_spa = min(cfg.ef_spatial, ef)
    spas = []
    for flag, q_int in ((ivm.FLAG_IF, wide), (ivm.FLAG_IS, point)):
        flags = jnp.full((b,), flag, jnp.int32)
        res_s = beam_search_flags(
            store,   # pre-insert store (plane, graph, tombstone mask)
            get_entry_batch_flags(eidx_old, q_int, flags, width=width),
            new32, q_int, flags,
            ef=ef, k=k_spa, backend=search_backend, width=width,
        )
        spas.append(res_s.ids)
    spa = jnp.concatenate(spas, axis=1)                    # (b, 2·k_spa)

    # ---- (1b) attribute candidates: the four Alg. 1 sort orders over the
    # live corpus (dead slots keyed +inf so they sort behind every rank).
    l_o, r_o = ivs[:, 0], ivs[:, 1]
    l_n, r_n = new_iv[:, 0], new_iv[:, 1]
    pairs = [
        (l_o, l_n), (r_o, r_n),
        ((l_o + r_o) * 0.5, (l_n + r_n) * 0.5),
        (r_o - l_o, r_n - l_n),
    ]
    n_live = jnp.sum(alive_old.astype(jnp.int32))
    w = max(cfg.ef_attribute // 8, 1)
    offs = jnp.arange(-w, w + 1)
    attrs = []
    for k_old, k_new in pairs:
        key = jnp.where(alive_old, k_old, jnp.inf)
        order = jnp.argsort(key)
        pos = jnp.searchsorted(key[order], k_new)
        attr_pos = jnp.clip(
            pos[:, None] + offs[None, :], 0, jnp.maximum(n_live - 1, 0)
        )
        attrs.append(order[attr_pos].astype(jnp.int32))
    cand = jnp.concatenate([spa.astype(jnp.int32)] + attrs, axis=1)
    c_c = jnp.clip(cand, 0, cap - 1)
    cand = jnp.where((cand >= 0) & alive_old[c_c], cand, -1)

    # ---- (2) prune the new nodes' out-edges (fused witness sweep).
    res = unified_prune(
        slot_c, cand, x2, iv2,
        m_if=cfg.max_edges_if, m_is=cfg.max_edges_is,
        alpha=cfg.alpha, unified=cfg.unified, backend=backend,
    )
    keep = min(M, res.order.shape[1])
    score = jnp.where(res.status > 0, res.dist, jnp.inf)
    sel = jnp.argsort(score, axis=1)[:, :keep]
    new_nbrs = jnp.where(
        jnp.isfinite(jnp.take_along_axis(score, sel, axis=1)),
        jnp.take_along_axis(res.order, sel, axis=1), -1,
    )
    new_stat = jnp.where(
        new_nbrs >= 0, jnp.take_along_axis(res.status, sel, axis=1), 0
    )
    if keep < M:
        new_nbrs = jnp.pad(new_nbrs, ((0, 0), (0, M - keep)), constant_values=-1)
        new_stat = jnp.pad(new_stat, ((0, 0), (0, M - keep)))
    nbrs2 = nbrs.at[slots].set(new_nbrs, mode="drop")
    status2 = status.at[slots].set(new_stat.astype(status.dtype), mode="drop")

    # ---- (3) reverse offers: u -> new appended into free slots under the
    # degree budgets, one sequential scan step per new node.  Targets are
    # the *distance-sorted candidate prefix* (2M closest), not just the
    # pruned out-neighbors — a fresh rebuild would integrate the new node
    # into those nodes' pools through the symmetric KNN of Alg. 1, and the
    # offer is the streaming approximation of that.  Within a step the
    # targets are distinct (deduped candidates), so the row/column scatters
    # are conflict-free; across steps the scan order keeps budgets exact.
    m_if, m_is = cfg.max_edges_if, cfg.max_edges_is
    k_off = min(2 * M, res.order.shape[1])
    offer_ids = res.order[:, :k_off]                       # (b, k_off)

    def offer_step(carry, inp):
        nb, st = carry
        nid, row, niv = inp              # (), (k_off,), (2,)
        u = jnp.clip(row, 0, cap - 1)
        urow = nb[u]                     # (k_off, M)
        ustat = st[u].astype(jnp.int32)
        present = (row >= 0) & (nid < cap)
        already = jnp.any(urow == nid, axis=1)
        has_free = jnp.any(urow < 0, axis=1)
        fcol = jnp.argmax(urow < 0, axis=1).astype(jnp.int32)
        live_e = urow >= 0
        cnt_if = jnp.sum(((ustat & ivm.FLAG_IF) > 0) & live_e, axis=1)
        cnt_is = jnp.sum(((ustat & ivm.FLAG_IS) > 0) & live_e, axis=1)
        iv_u = iv2[u]                    # (M, 2)
        overlap = jnp.maximum(iv_u[:, 0], niv[0]) <= jnp.minimum(iv_u[:, 1], niv[1])
        bits = (
            jnp.where(cnt_if < m_if, ivm.FLAG_IF, 0)
            | jnp.where((cnt_is < m_is) & overlap, ivm.FLAG_IS, 0)
        )
        do = present & ~already & has_free & (bits > 0)
        tgt = jnp.where(do, u, cap)
        nb = nb.at[tgt, fcol].set(nid.astype(jnp.int32), mode="drop")
        st = st.at[tgt, fcol].set(bits.astype(st.dtype), mode="drop")
        return (nb, st), None

    (nbrs2, status2), _ = jax.lax.scan(
        offer_step, (nbrs2, status2), (slots, offer_ids, new_iv)
    )

    eidx = build_entry_index(iv2, node_mask=alive2)
    out = store.replace(
        plane=plane2, rerank=rerank2, intervals=iv2, nbrs=nbrs2,
        status=status2, entry=eidx, alive=alive2, free=free2,
    )
    return out, slots


def insert_batch(
    index: UGIndex,
    new_x,
    new_intervals,
    *,
    valid=None,
    ef: int | None = None,
    width: int = 4,
    backend: str | None = None,
    search_backend: str | None = None,
) -> UGIndex:
    """Insert a batch of objects; returns a new UGIndex (functional update).

    ``valid`` masks pad rows of a shape-bucketed batch (ServeEngine.upsert);
    ``ef`` is the candidate-acquisition beam width (default
    ``max(2·ef_spatial, 48)``); ``backend`` selects the prune-sweep kernel
    and ``search_backend`` the acquisition search pipeline.

    Nodes of one batch are mutually invisible during candidate acquisition
    (candidates and offer targets come from the *pre-insert* live set, so
    the whole batch is one data-parallel jitted step).  Keep the batch
    small relative to the live corpus — ``ServeEngine.upsert`` chunks at
    half the live count so earlier chunks integrate later ones.
    """
    new_x = jnp.atleast_2d(jnp.asarray(new_x))
    new_iv = jnp.atleast_2d(jnp.asarray(new_intervals))
    b = new_x.shape[0]
    cfg = index.config
    if valid is None:
        valid = jnp.ones((b,), bool)
    else:
        valid = jnp.asarray(valid, bool)
    need = int(jnp.sum(valid))
    store = index.store.grow(need, cfg.max_edges_if + cfg.max_edges_is)
    if ef is None:
        ef = max(2 * cfg.ef_spatial, 48)
    store2, _ = _insert_core(
        store, new_x, new_iv, valid,
        cfg=cfg, backend=backend if backend is not None else cfg.prune_backend,
        search_backend=search_backend, ef=ef, width=width,
    )
    return index.with_store(store2)


def insert(index: UGIndex, new_x, new_intervals) -> UGIndex:
    """Thin wrapper kept for the PR-1 call sites: one batched insert."""
    return insert_batch(index, new_x, new_intervals)


# ------------------------------------------------------------------- delete
def _merge_repair_rows(
    u, surv_ids, surv_st, cand, x, ivs,
    *, m_if, m_is, alpha, unified, backend, M,
):
    """Conservative witness repair for a block of rows.

    Surviving edges (``surv_ids``/``surv_st``, -1 holes) are kept verbatim —
    the PR-1 lesson: re-pruning existing rows forgets the build's Alg. 2
    repair edges and measurably degrades recall.  The candidate pool
    (survivors ∪ bridges) runs through the fused Φ witness sweep so each
    *bridge* is accepted only if no closer pool member witnesses it; accepted
    bridges are appended in ascending-distance order under what remains of
    the per-semantics degree budgets.  Returns ``(nbrs_rows, stat_rows,
    w_flat, v_flat)`` with (w, v) the Alg. 2 repair pairs in global ids.
    """
    res = unified_prune(
        u, cand, x, ivs,
        m_if=m_if, m_is=m_is, alpha=alpha, unified=unified, backend=backend,
    )
    st32 = res.status.astype(jnp.int32)
    surv32 = surv_st.astype(jnp.int32)
    surv_ok = surv_ids >= 0
    # Bridge = pool member that survived the witness sweep and is not an
    # existing edge (membership is an O(P·M) integer compare — no (·,C,C)).
    is_surv = jnp.any(
        res.order[:, :, None] == jnp.where(surv_ok, surv_ids, -2)[:, None, :],
        axis=-1,
    )
    acc0 = (st32 > 0) & ~is_surv & (res.order >= 0)
    bif = acc0 & ((st32 & ivm.FLAG_IF) > 0)
    bis = acc0 & ((st32 & ivm.FLAG_IS) > 0)
    cnt_if = jnp.sum(((surv32 & ivm.FLAG_IF) > 0) & surv_ok, axis=1)
    cnt_is = jnp.sum(((surv32 & ivm.FLAG_IS) > 0) & surv_ok, axis=1)
    if_keep = bif & (jnp.cumsum(bif, axis=1) - 1 + cnt_if[:, None] < m_if)
    is_keep = bis & (jnp.cumsum(bis, axis=1) - 1 + cnt_is[:, None] < m_is)
    bits = (
        jnp.where(if_keep, ivm.FLAG_IF, 0) | jnp.where(is_keep, ivm.FLAG_IS, 0)
    )
    bridge_ids = jnp.where(bits > 0, res.order, -1)
    # Merge: survivors first (original column order and bits), then accepted
    # bridges by distance; compact the -1 holes out with one stable sort.
    ids_cat = jnp.concatenate([surv_ids, bridge_ids], axis=1)
    st_cat = jnp.concatenate([surv32, bits], axis=1)
    prio = jax.lax.broadcasted_iota(jnp.int32, ids_cat.shape, 1)
    key = jnp.where(ids_cat >= 0, prio, jnp.iinfo(jnp.int32).max)
    order = jnp.argsort(key, axis=1)[:, :M]
    nb_rows = jnp.take_along_axis(ids_cat, order, axis=1)
    st_rows = jnp.take_along_axis(st_cat, order, axis=1)
    dead = jnp.take_along_axis(key, order, axis=1) == jnp.iinfo(jnp.int32).max
    nb_rows = jnp.where(dead, -1, nb_rows)
    st_rows = jnp.where(dead, 0, st_rows)
    w_flat = jnp.concatenate(
        [res.repair_if.reshape(-1), res.repair_is.reshape(-1)]
    )
    v_flat = jnp.concatenate([
        jnp.where(res.repair_if >= 0, res.order, -1).reshape(-1),
        jnp.where(res.repair_is >= 0, res.order, -1).reshape(-1),
    ])
    return nb_rows, st_rows, w_flat, v_flat


@functools.partial(
    jax.jit,
    static_argnames=("m_if", "m_is", "alpha", "unified", "backend", "P", "block"),
)
def _repair_core(
    x, ivs, nbrs, status, del_mask, in_sets, rows,
    *,
    m_if: int,
    m_is: int,
    alpha: float,
    unified: bool,
    backend: str | None,
    P: int,
    block: int,
):
    """Repair sweep round 1: re-wire the touched rows through the deleted
    nodes' neighborhoods (blocked ``lax.map``, DESIGN.md §11).

    Per touched row ``u``: pool = (surviving out-edges) ∪ (out-rows and
    in-neighbor lists of u's deleted neighbors — both sides of the deleted
    node's neighborhood, ids only), deduped with the sort-based
    ``dedup_first``, scored one row at a time by ``ops.expand_score`` (the
    ``(B, M+2M², d)`` bridge gather is never materialized), truncated to
    the ``P`` closest, and witness-filtered by the fused Φ sweep.
    """
    cap, M = nbrs.shape
    R = rows.shape[0]
    rows_c = jnp.clip(rows, 0, cap - 1)
    row_ok = rows >= 0

    def one_block(args):
        u, ok = args                                       # (block,)
        own = nbrs[u]                                      # (block, M)
        own_c = jnp.clip(own, 0, cap - 1)
        own_del = (own >= 0) & del_mask[own_c]
        own_ids = jnp.where((own >= 0) & ~own_del, own, -1)
        own_st = jnp.where(own_ids >= 0, status[u], 0)
        # Bridge candidates: out-rows ∪ in-neighbor lists of u's deleted
        # neighbors (ids only — never gathered as vectors).
        bridge = jnp.where(
            own_del[:, :, None],
            jnp.concatenate([nbrs[own_c], in_sets[own_c]], axis=-1), -1,
        )
        bridge = bridge.reshape(u.shape[0], 2 * M * M)
        b_c = jnp.clip(bridge, 0, cap - 1)
        bridge = jnp.where((bridge >= 0) & ~del_mask[b_c], bridge, -1)
        cand0 = jnp.concatenate([own_ids, bridge], axis=1)  # (block, M+2M²)
        cand0 = jnp.where(cand0 == u[:, None], -1, cand0)
        cand0 = jnp.where(dedup_first(cand0, cand0 >= 0), cand0, -1)
        # Distance-ranked pool truncation through the expand-score kernel.
        d0 = ops.expand_score(x, cand0, x[u], backend=backend)
        neg, sel = jax.lax.top_k(-d0, P)
        cand = jnp.where(
            jnp.isfinite(neg), jnp.take_along_axis(cand0, sel, axis=1), -1
        )
        nb_rows, st_rows, w_flat, v_flat = _merge_repair_rows(
            u, own_ids, own_st, cand, x, ivs,
            m_if=m_if, m_is=m_is, alpha=alpha, unified=unified,
            backend=backend, M=M,
        )
        # Untouched pad rows keep their original contents.
        nb_rows = jnp.where(ok[:, None], nb_rows, own)
        st_rows = jnp.where(ok[:, None], st_rows, status[u].astype(jnp.int32))
        # (w, v) layout is [IF half | IS half], each block-major.
        okm = jnp.tile(jnp.repeat(ok, cand.shape[1]), 2)
        w_flat = jnp.where(okm, w_flat, -1)
        return nb_rows, st_rows, w_flat, v_flat

    nb_new, st_new, w_w, w_v = jax.lax.map(
        one_block, (rows_c.reshape(-1, block), row_ok.reshape(-1, block))
    )
    nb_new = nb_new.reshape(R, M)
    st_new = st_new.reshape(R, M)
    tgt = jnp.where(row_ok, rows_c, cap)
    nbrs2 = nbrs.at[tgt].set(nb_new, mode="drop")
    status2 = status.at[tgt].set(st_new.astype(status.dtype), mode="drop")
    return nbrs2, status2, w_w.reshape(-1), w_v.reshape(-1)


@functools.partial(
    jax.jit,
    static_argnames=("m_if", "m_is", "alpha", "unified", "backend", "block"),
)
def _repair_round(
    x, ivs, nbrs, status, del_mask, repair_sets, rows,
    *,
    m_if: int,
    m_is: int,
    alpha: float,
    unified: bool,
    backend: str | None,
    block: int,
):
    """Repair rounds ≥ 2 (Alg. 2 restricted to affected rows): pool =
    current out-edges ∪ witness repair set, fused-prune, scatter back."""
    cap, M = nbrs.shape
    R = rows.shape[0]
    rows_c = jnp.clip(rows, 0, cap - 1)
    row_ok = rows >= 0

    def one_block(args):
        u, ok = args
        own = nbrs[u]
        own_ids = jnp.where(
            (own >= 0) & ~del_mask[jnp.clip(own, 0, cap - 1)], own, -1
        )
        own_st = jnp.where(own_ids >= 0, status[u], 0)
        rep = repair_sets[u]
        cand = jnp.concatenate([own_ids, rep], axis=1)
        c_c = jnp.clip(cand, 0, cap - 1)
        cand = jnp.where((cand >= 0) & ~del_mask[c_c], cand, -1)
        cand = jnp.where(cand == u[:, None], -1, cand)
        cand = jnp.where(dedup_first(cand, cand >= 0), cand, -1)
        nb_rows, st_rows, w_flat, v_flat = _merge_repair_rows(
            u, own_ids, own_st, cand, x, ivs,
            m_if=m_if, m_is=m_is, alpha=alpha, unified=unified,
            backend=backend, M=M,
        )
        nb_rows = jnp.where(ok[:, None], nb_rows, own)
        st_rows = jnp.where(ok[:, None], st_rows, status[u].astype(jnp.int32))
        okm = jnp.tile(jnp.repeat(ok, cand.shape[1]), 2)
        w_flat = jnp.where(okm, w_flat, -1)
        return nb_rows, st_rows, w_flat, v_flat

    nb_new, st_new, w_w, w_v = jax.lax.map(
        one_block, (rows_c.reshape(-1, block), row_ok.reshape(-1, block))
    )
    tgt = jnp.where(row_ok, rows_c, cap)
    nbrs2 = nbrs.at[tgt].set(nb_new.reshape(R, M), mode="drop")
    status2 = status.at[tgt].set(
        st_new.reshape(R, M).astype(status.dtype), mode="drop"
    )
    return nbrs2, status2, w_w.reshape(-1), w_v.reshape(-1)


def _pad_rows_1d(idx: np.ndarray, block: int) -> jnp.ndarray:
    r = pad_to(max(idx.size, 1), block)
    out = np.full((r,), -1, np.int32)
    out[: idx.size] = idx
    return jnp.asarray(out)


def repair_deleted(
    index: UGIndex,
    *,
    repair_iters: int = 1,
    pool: int | None = None,
    backend: str | None = None,
    block: int = 256,
) -> UGIndex:
    """Detach every tombstoned-but-still-routable node (DESIGN.md §11).

    Re-wires all in-neighbors of tombstoned nodes through the tombstones'
    neighborhoods: surviving edges are kept verbatim, witness-filtered
    bridges refill the freed budget, and the tombstoned rows are cleared
    and marked reusable.  ``pool`` caps the per-row candidate pool (default
    ``4·M``); ``repair_iters`` adds Alg. 2 witness-repair rounds.
    """
    store = index.store
    alive, free = store.masks()
    cfg = index.config
    cap = store.capacity
    # budget headroom for the bridges + the f32 pruning view of the vectors
    widened = store.widen_rows(cfg.max_edges_if + cfg.max_edges_is)
    nbrs, status = widened.nbrs, widened.status
    x = store.vectors_f32()
    M = nbrs.shape[1]
    del_mask = (~alive) & (~free)
    backend = backend if backend is not None else cfg.prune_backend
    kw = dict(
        m_if=cfg.max_edges_if, m_is=cfg.max_edges_is, alpha=cfg.alpha,
        unified=cfg.unified, backend=backend,
    )

    to_del = (nbrs >= 0) & del_mask[jnp.clip(nbrs, 0, cap - 1)]
    touched = jnp.any(to_del, axis=1) & alive
    t_idx = np.flatnonzero(np.asarray(touched))            # one host sync
    if t_idx.size:
        P = pool if pool is not None else min(4 * M, M + 2 * M * M)
        rows = _pad_rows_1d(t_idx, block)
        # In-neighbor lists of the deleted nodes (the other half of their
        # neighborhood): one sort/segment-rank scatter over the edge list.
        src = jnp.broadcast_to(
            jnp.arange(cap, dtype=jnp.int32)[:, None], nbrs.shape
        )
        in_sets = scatter_repairs(
            jnp.where(to_del, nbrs, -1).reshape(-1),
            jnp.where(to_del, src, -1).reshape(-1),
            cap, M,
        )
        nbrs, status, w_w, w_v = _repair_core(
            x, store.intervals, nbrs, status, del_mask, in_sets, rows,
            P=P, block=block, **kw,
        )
        for _ in range(1, repair_iters):
            rep = scatter_repairs(w_w, w_v, cap, cfg.repair_width)
            again = jnp.any(rep >= 0, axis=1) & alive
            a_idx = np.flatnonzero(np.asarray(again))
            if a_idx.size == 0:
                break
            rows = _pad_rows_1d(a_idx, block)
            nbrs, status, w_w, w_v = _repair_round(
                x, store.intervals, nbrs, status, del_mask, rep, rows,
                block=block, **kw,
            )

    # Detached: clear the dead rows and hand their slots to the allocator.
    nbrs = jnp.where(del_mask[:, None], -1, nbrs)
    status = jnp.where(del_mask[:, None], 0, status)
    return index.with_store(
        store.replace(nbrs=nbrs, status=status, free=free | del_mask)
    )


def delete_batch(
    index: UGIndex,
    ids,
    *,
    repair: bool = True,
    repair_iters: int = 1,
    pool: int | None = None,
    backend: str | None = None,
    block: int = 256,
) -> UGIndex:
    """Delete a batch of node ids; returns a new UGIndex (functional update).

    The nodes are tombstoned immediately (search routes through them but
    never surfaces them; the entry structure re-certifies over live nodes).
    With ``repair=True`` (default) the iterative-repair sweep then detaches
    them so their slots are reusable; ``repair=False`` defers that to a
    later :func:`repair_deleted` or :func:`compact` (cheap deletes, slight
    search overhead while tombstones accumulate).
    """
    ids = jnp.atleast_1d(jnp.asarray(ids, jnp.int32))
    alive, free = index.store.masks()
    cap = index.store.capacity
    tgt = jnp.where(ids >= 0, ids, cap)
    del_mask = jnp.zeros((cap,), bool).at[tgt].set(True, mode="drop") & alive
    alive2 = alive & ~del_mask
    out = index.with_store(index.store.replace(
        entry=build_entry_index(index.store.intervals, node_mask=alive2),
        alive=alive2, free=free,
    ))
    if repair:
        out = repair_deleted(
            out, repair_iters=repair_iters, pool=pool, backend=backend,
            block=block,
        )
    return out


# ------------------------------------------------------------------ compact
def compact(index: UGIndex) -> UGIndex:
    """Physically drop dead slots: gather live rows, remap neighbor ids,
    re-trim the trailing all-dead columns (undoing the update-time row
    widening), rebuild the entry structure.  Returns a static UGIndex.

    Unrepaired tombstones (from ``delete(..., repair=False)``) are still
    routable, so dropping them here without bridging would sever the
    monotone paths through them — compact therefore runs the repair sweep
    first when any exist.
    """
    if index.alive is None:
        return index
    alive0, free0 = index.store.masks()
    if bool(jnp.any((~alive0) & (~free0))):
        index = repair_deleted(index)
    store = index.store
    cap = store.capacity
    live = np.asarray(store.alive)
    old_ids = np.flatnonzero(live)
    remap = np.full((cap,), -1, np.int32)
    remap[old_ids] = np.arange(old_ids.size, dtype=np.int32)
    nb = np.asarray(store.nbrs)[old_ids]
    st = np.asarray(store.status)[old_ids]
    nb2 = np.where(nb >= 0, remap[np.clip(nb, 0, cap - 1)], -1)
    st2 = np.where(nb2 >= 0, st, 0)
    order = np.argsort(nb2 < 0, axis=1, kind="stable")  # holes to the back
    nb2 = np.take_along_axis(nb2, order, axis=1)
    st2 = np.take_along_axis(st2, order, axis=1)
    live_cols = max(int((nb2 >= 0).sum(axis=1).max()) if nb2.size else 1, 1)
    nb2, st2 = nb2[:, :live_cols], st2[:, :live_cols]
    rows = jnp.asarray(old_ids)
    ivs = store.intervals[rows]
    gather_plane = lambda p: None if p is None else dataclasses.replace(
        p, data=p.data[rows]
    )
    return index.with_store(store.replace(
        plane=gather_plane(store.plane), rerank=gather_plane(store.rerank),
        intervals=ivs,
        nbrs=jnp.asarray(nb2), status=jnp.asarray(st2.astype(st.dtype)),
        entry=build_entry_index(ivs), alive=None, free=None,
    ))


# ----------------------------------------------------------- memory profile
def update_memory_profile(
    backend: str,
    *,
    b: int = 8,
    cap: int = 1024,
    d: int = 16,
    M: int = 16,
    P: int = 48,   # ≠ the pallas sweep's bb=32 row tile (a (bb, C) working
    width: int = 4,  # row would otherwise read as a square (P, P) tensor)
    ef: int = 32,
) -> dict:
    """Trace one insert step and one repair sweep; report their intermediate
    profile (the ISSUE-4 acceptance check, à la ``search_step_memory_profile``).

    Returns ``{"peak_bytes", "quadratic_cc", "gather_bcd"}``:

    * ``quadratic_cc`` — any square ``(·, C, C)`` tensor over the insert
      candidate-pool width, the search candidate width ``W·M``, the repair
      pool ``P``, or the raw bridge width ``M+M²`` (witness matrices,
      pairwise dedup);
    * ``gather_bcd`` — a ``(·, W·M, d)`` search gather or ``(·, M+M², d)``
      bridge gather.  The ``(·, P, d)`` / ``(·, C_pool, d)`` row gathers
      feeding the prune sweep are its kernel inputs (DESIGN.md §9) and are
      allowed.

    ``backend="xla" | "pallas"`` must show neither; ``"legacy"`` routes the
    pre-fusion prune/expand baselines and shows both.
    """
    from repro.core.store import IndexStore, VectorPlane
    from repro.kernels.prune_sweep import _iter_eqn_avals

    f32, i32 = jnp.float32, jnp.int32
    cfg = UGConfig(
        ef_spatial=16, ef_attribute=32, max_edges_if=M, max_edges_is=M,
        iterations=1, repair_width=8, exact_spatial=True,
    )
    k_spa = min(cfg.ef_spatial, ef)
    w = max(cfg.ef_attribute // 8, 1)
    c_pool = 2 * k_spa + 4 * (2 * w + 1)  # insert candidate-pool width
    c_search = max(min(width, ef), 1) * M  # fused search candidate width
    c_bridge = M + 2 * M * M               # raw repair bridge width

    store_sds = IndexStore(
        plane=VectorPlane("f32", jax.ShapeDtypeStruct((cap, d), f32)),
        rerank=None,
        intervals=jax.ShapeDtypeStruct((cap, 2), f32),
        nbrs=jax.ShapeDtypeStruct((cap, M), i32),
        status=jax.ShapeDtypeStruct((cap, M), jnp.uint8),
        entry=None,
        alive=jax.ShapeDtypeStruct((cap,), jnp.bool_),
        free=jax.ShapeDtypeStruct((cap,), jnp.bool_),
    )
    insert_args = (
        store_sds,
        jax.ShapeDtypeStruct((b, d), f32),
        jax.ShapeDtypeStruct((b, 2), f32),
        jax.ShapeDtypeStruct((b,), jnp.bool_),
    )
    ins = jax.make_jaxpr(
        functools.partial(
            _insert_core, cfg=cfg, backend=backend,
            search_backend=backend, ef=ef, width=width,
        )
    )(*insert_args)

    repair_args = (
        jax.ShapeDtypeStruct((cap, d), f32),
        jax.ShapeDtypeStruct((cap, 2), f32),
        jax.ShapeDtypeStruct((cap, M), i32),
        jax.ShapeDtypeStruct((cap, M), jnp.uint8),
        jax.ShapeDtypeStruct((cap,), jnp.bool_),   # del_mask
        jax.ShapeDtypeStruct((cap, M), i32),       # in_sets
        jax.ShapeDtypeStruct((b,), i32),           # rows
    )
    rep = jax.make_jaxpr(
        functools.partial(
            _repair_core, m_if=M, m_is=M, alpha=1.0, unified=True,
            backend=backend, P=P, block=b,
        )
    )(*repair_args)

    banned_sq = {c_pool, c_search, c_bridge, P}
    peak = 0
    quadratic = False
    gather = False
    for closed in (ins, rep):
        for aval in _iter_eqn_avals(closed.jaxpr):
            size = (
                int(aval.size) * aval.dtype.itemsize
                if aval.shape else aval.dtype.itemsize
            )
            peak = max(peak, size)
            if (
                len(aval.shape) >= 2
                and aval.shape[-1] == aval.shape[-2]
                and aval.shape[-1] in banned_sq
            ):
                quadratic = True
            if len(aval.shape) >= 3 and aval.shape[-2:] in (
                (c_search, d), (c_bridge, d),
            ):
                gather = True
    return {"peak_bytes": peak, "quadratic_cc": quadratic, "gather_bcd": gather}
