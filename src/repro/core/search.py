"""Interval-aware beam search over the unified graph (paper Alg. 4).

TPU adaptation (DESIGN.md §2): the per-query priority queues of the paper
become a fixed-width ``(B, ef)`` beam advanced by a ``lax.while_loop``; the
visited hash-set becomes an exact per-query bitmap updated with one
deduplicated scatter-add per step; each expansion scores all ``M`` neighbors
of the selected node in a single gather + matmul.  The search never leaves
the query-valid subgraph — only neighbors whose semantic bit is set *and*
whose interval satisfies the query predicate enter the beam (Alg. 4 lines
11-20); structural heredity (Thm 4.1) is what makes this correct.

Two generations of the hot loop live here (DESIGN.md §8):

* ``backend="legacy"`` — the original per-query ``vmap`` loop: one node
  expanded per step, full ``(ef + M)`` argsort per step;
* ``backend="pallas" | "xla"`` — the fused multi-expansion pipeline: the
  whole batch steps together, each step expands the ``W`` best unexpanded
  frontier nodes per query, scores all ``W·M`` neighbors with one gather +
  one batched matmul, and folds them into the sorted beam with the bitonic
  partial-merge kernel (``kernels/beam_merge.py``) instead of an argsort.
  The two fused backends run the identical comparator network and return
  bit-identical ids; ``xla`` is the interpretable CPU-CI reference.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import intervals as iv
from repro.core.entry import EntryIndex, get_entry, get_entry_batch
from repro.kernels import ops
from repro.kernels.beam_merge import PAD_PAYLOAD, next_pow2


class SearchResult(NamedTuple):
    ids: jnp.ndarray    # (B, k) int32 node ids, ascending distance, -1 pad
    dist: jnp.ndarray   # (B, k) f32 squared distances (+inf pad)
    steps: jnp.ndarray  # (B,) int32 expansion count (work metric for QPS)


def _bitmap_test(bitmap: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    word = jnp.clip(ids, 0, None) >> 5
    bit = jnp.clip(ids, 0, None) & 31
    return ((bitmap[word] >> bit) & 1).astype(bool)


def _bitmap_set(bitmap: jnp.ndarray, ids: jnp.ndarray, fresh: jnp.ndarray) -> jnp.ndarray:
    """OR the bits of ``ids[fresh]`` into the bitmap with one scatter-add.

    Neighbor lists are duplicate-free (build-time invariant) and ``fresh``
    excludes already-set bits, so add == or.
    """
    nwords = bitmap.shape[0]
    word = jnp.where(fresh, ids >> 5, nwords)  # out-of-range rows are dropped
    bit = (ids & 31).astype(jnp.uint32)
    return bitmap.at[word].add(
        jnp.where(fresh, jnp.uint32(1) << bit, jnp.uint32(0)), mode="drop"
    )


def _search_one(
    q_v: jnp.ndarray,        # (d,)
    q_int: jnp.ndarray,      # (2,)
    start: jnp.ndarray,      # () int32, -1 = no valid entry
    x: jnp.ndarray,          # (n, d)
    intervals: jnp.ndarray,  # (n, 2)
    nbrs: jnp.ndarray,       # (n, M)
    status: jnp.ndarray,     # (n, M) uint8
    sem_flag: int,
    sem_is_filter: bool,     # True for IF/RF (obj ⊆ query), False for IS/RS
    ef: int,
    max_steps: int,
):
    n, d = x.shape
    M = nbrs.shape[1]
    nwords = (n + 31) // 32

    q32 = q_v.astype(jnp.float32)

    def dist_to(ids):
        xs = x[jnp.clip(ids, 0, n - 1)].astype(jnp.float32)
        diff = xs - q32[None, :]
        return jnp.sum(diff * diff, axis=-1)

    has_entry = start >= 0
    start_c = jnp.clip(start, 0, n - 1)

    beam_ids = jnp.full((ef,), -1, jnp.int32).at[0].set(jnp.where(has_entry, start_c, -1))
    beam_d = jnp.full((ef,), jnp.inf, jnp.float32).at[0].set(
        jnp.where(has_entry, dist_to(start_c[None])[0], jnp.inf)
    )
    expanded = jnp.zeros((ef,), bool)
    visited = jnp.zeros((nwords,), jnp.uint32)
    visited = _bitmap_set(visited, start_c[None], has_entry[None])

    def predicate(obj_int):
        if sem_is_filter:
            return iv.contains(q_int[None, :], obj_int)
        return iv.contains(obj_int, q_int[None, :])

    def cond(state):
        beam_ids, beam_d, expanded, visited, steps = state
        frontier = (~expanded) & jnp.isfinite(beam_d)
        return jnp.any(frontier) & (steps < max_steps)

    def body(state):
        beam_ids, beam_d, expanded, visited, steps = state
        # ExtractMin over unexpanded beam entries (Alg. 4 line 6).
        sel_d = jnp.where(expanded, jnp.inf, beam_d)
        j = jnp.argmin(sel_d)
        u = beam_ids[j]
        expanded = expanded.at[j].set(True)
        u_c = jnp.clip(u, 0, n - 1)

        nb = nbrs[u_c]                      # (M,)
        st = status[u_c]
        present = nb >= 0
        nb_c = jnp.clip(nb, 0, n - 1)
        seen = _bitmap_test(visited, nb_c) | ~present

        sem_ok = (st & sem_flag) > 0
        pred_ok = predicate(intervals[nb_c])
        valid = present & ~seen & sem_ok & pred_ok
        # Visited semantics follow the σ-projection G^σ the theory searches
        # (Thm 3.3): mark nodes that were scored (valid) or are node-level
        # dead for this query (predicate fails — can never become valid), but
        # NOT nodes skipped only because *this* edge's σ-bit is off: they may
        # be reachable via another σ-active edge.  (Deviation from Alg. 4's
        # literal line 10; see DESIGN.md §6.)
        visited = _bitmap_set(visited, nb_c, present & ~seen & (valid | ~pred_ok))
        nd = jnp.where(valid, dist_to(nb_c), jnp.inf)

        # Merge candidates into the beam; keep ef best (RemoveMax of Alg. 4).
        all_ids = jnp.concatenate([beam_ids, jnp.where(valid, nb_c, -1)])
        all_d = jnp.concatenate([beam_d, nd])
        all_exp = jnp.concatenate([expanded, jnp.zeros((M,), bool)])
        order = jnp.argsort(all_d)[:ef]
        return (
            all_ids[order],
            all_d[order],
            all_exp[order],
            visited,
            steps + 1,
        )

    state = (beam_ids, beam_d, expanded, visited, jnp.int32(0))
    beam_ids, beam_d, expanded, visited, steps = jax.lax.while_loop(cond, body, state)
    return beam_ids, beam_d, steps


def _beam_search_fused(
    x: jnp.ndarray,          # (n, d)
    intervals: jnp.ndarray,  # (n, 2)
    nbrs: jnp.ndarray,       # (n, M)
    status: jnp.ndarray,     # (n, M) uint8
    entry_ids: jnp.ndarray,  # (B, We) int32, -1 padded
    q_v: jnp.ndarray,        # (B, d)
    q_int: jnp.ndarray,      # (B, 2)
    *,
    sem_flag: int,
    sem_is_filter: bool,
    ef: int,
    k: int,
    max_steps: int,
    width: int,
    backend: str,
) -> SearchResult:
    """Fused multi-expansion Alg. 4 (DESIGN.md §8).

    The beam is ``E = next_pow2(ef)`` wide (padded with ``+inf``/``-1``) and
    kept ascending under the total order ``(dist, payload)``; each payload
    packs ``id << 1 | expanded``.  Every step the ``W`` best unexpanded
    entries are expanded at once; rows whose frontier is exhausted are
    natural no-ops, so the batch shares one ``while_loop``.
    """
    n, d = x.shape
    M = nbrs.shape[1]
    B = q_v.shape[0]
    W = max(min(width, ef), 1)
    E = next_pow2(ef)
    C = W * M
    nwords = (n + 31) // 32

    q32 = q_v.astype(jnp.float32)
    qn = jnp.sum(q32 * q32, axis=-1)                       # (B,)
    xn = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)      # (n,)

    bitmap_test = jax.vmap(_bitmap_test)
    bitmap_set = jax.vmap(_bitmap_set)

    def score(ids_c, valid):
        """One gather + one batched matmul: ‖q−x‖² = ‖x‖² + ‖q‖² − 2·x·q."""
        rows = x[ids_c].astype(jnp.float32)                # (B, C, d) gather
        ip = jnp.einsum("bcd,bd->bc", rows, q32)
        dist = jnp.maximum(xn[ids_c] + qn[:, None] - 2.0 * ip, 0.0)
        return jnp.where(valid, dist, jnp.inf)

    def predicate(obj_int):
        if sem_is_filter:
            return iv.contains(q_int[:, None, :], obj_int)
        return iv.contains(obj_int, q_int[:, None, :])

    def merge(beam_d, beam_p, cand_d, cand_p):
        return ops.beam_merge(beam_d, beam_p, cand_d, cand_p, backend=backend)

    def first_occurrence(ids_c, flag):
        """Per row, keep ``flag`` only on the first candidate slot carrying
        each id (duplicates across the W neighbor lists collapse to one)."""
        same = ids_c[:, :, None] == ids_c[:, None, :]      # (B, C, C)
        idx = jnp.arange(ids_c.shape[1], dtype=jnp.int32)
        earlier = idx[:, None] > idx[None, :]
        return flag & ~jnp.any(same & earlier[None] & flag[:, None, :], axis=2)

    # ---- seed: merge the (deduped) entry batch into an empty beam
    ent_valid = entry_ids >= 0
    ent_c = jnp.clip(entry_ids, 0, n - 1)
    ent_d = score(ent_c, ent_valid)
    ent_p = jnp.where(ent_valid, ent_c << 1, PAD_PAYLOAD)
    beam_d = jnp.full((B, E), jnp.inf, jnp.float32)
    beam_p = jnp.full((B, E), PAD_PAYLOAD, jnp.int32)
    beam_d, beam_p = merge(beam_d, beam_p, ent_d, ent_p)
    visited = bitmap_set(jnp.zeros((B, nwords), jnp.uint32), ent_c, ent_valid)

    rowi = jnp.arange(B, dtype=jnp.int32)[:, None]
    iters_cap = (max_steps + W - 1) // W

    def cond(state):
        beam_d, beam_p, visited, steps, it = state
        frontier = ((beam_p & 1) == 0) & jnp.isfinite(beam_d)
        return jnp.any(frontier) & (it < iters_cap)

    def body(state):
        beam_d, beam_p, visited, steps, it = state
        # ExtractMin_W: beam is sorted, so top_k picks the W best unexpanded.
        sel_d = jnp.where((beam_p & 1) == 0, beam_d, jnp.inf)
        neg, sel_idx = jax.lax.top_k(-sel_d, W)            # (B, W)
        sel_ok = jnp.isfinite(-neg)
        u = jnp.take_along_axis(beam_p >> 1, sel_idx, axis=-1)
        mark = jnp.zeros((B, E), jnp.int32).at[rowi, sel_idx].max(
            sel_ok.astype(jnp.int32)
        )
        beam_p = beam_p | mark

        u_c = jnp.clip(u, 0, n - 1)
        nb = jnp.where(sel_ok[..., None], nbrs[u_c], -1).reshape(B, C)
        st = status[u_c].reshape(B, C)
        present = nb >= 0
        nb_c = jnp.clip(nb, 0, n - 1)
        seen = bitmap_test(visited, nb_c) | ~present

        sem_ok = (st & sem_flag) > 0
        pred_ok = predicate(intervals[nb_c])
        cand_ok = present & ~seen & sem_ok & pred_ok
        # Same visited semantics as the legacy path (DESIGN.md §6): mark
        # scored and node-dead candidates, never edge-masked ones.  Across
        # the W lists one id may repeat — score/mark only its first
        # *eligible* occurrence so the scatter-add stays an OR.
        valid = first_occurrence(nb_c, cand_ok)
        to_mark = first_occurrence(nb_c, present & ~seen & (cand_ok | ~pred_ok))
        visited = bitmap_set(visited, nb_c, to_mark)

        cand_d = score(nb_c, valid)
        cand_p = jnp.where(valid, nb_c << 1, PAD_PAYLOAD)
        beam_d, beam_p = merge(beam_d, beam_p, cand_d, cand_p)
        steps = steps + jnp.sum(sel_ok, axis=-1, dtype=jnp.int32)
        return beam_d, beam_p, visited, steps, it + 1

    state = (beam_d, beam_p, visited, jnp.zeros((B,), jnp.int32), jnp.int32(0))
    beam_d, beam_p, visited, steps, _ = jax.lax.while_loop(cond, body, state)

    dist = beam_d[:, :k]                                   # beam is sorted
    ids = jnp.where(jnp.isfinite(dist), beam_p[:, :k] >> 1, -1)
    return SearchResult(ids, dist, steps)


@functools.partial(
    jax.jit, static_argnames=("sem", "ef", "k", "max_steps", "backend", "width")
)
def beam_search(
    x: jnp.ndarray,
    intervals: jnp.ndarray,
    nbrs: jnp.ndarray,
    status: jnp.ndarray,
    entry_ids: jnp.ndarray,   # (B,) or (B, We) int32 entry node(s) (Alg. 5)
    q_v: jnp.ndarray,         # (B, d)
    q_int: jnp.ndarray,       # (B, 2)
    *,
    sem: iv.Semantics,
    ef: int,
    k: int,
    max_steps: int = 0,
    backend: str | None = None,
    width: int = 4,
) -> SearchResult:
    """Batched Alg. 4.  ``max_steps=0`` derives a generous default (8·ef+32).

    ``backend`` selects the hot-loop implementation: ``"pallas"`` /
    ``"xla"`` are the fused multi-expansion pipeline (bit-identical to each
    other; default — pallas on TPU, xla on CPU), ``"legacy"`` the original
    one-node-per-step argsort loop.  ``width`` is the fused frontier width W.
    """
    steps_cap = max_steps if max_steps > 0 else 8 * ef + 32
    sem_is_filter = sem in (iv.Semantics.IF, iv.Semantics.RF)
    if backend != "legacy":
        backend = ops.resolve_backend(backend)
        ent = entry_ids[:, None] if entry_ids.ndim == 1 else entry_ids
        return _beam_search_fused(
            x, intervals, nbrs, status, ent, q_v, q_int,
            sem_flag=sem.flag, sem_is_filter=sem_is_filter,
            ef=ef, k=k, max_steps=steps_cap, width=width, backend=backend,
        )
    entry_one = entry_ids if entry_ids.ndim == 1 else entry_ids[:, 0]
    run = jax.vmap(
        lambda qv, qi, s: _search_one(
            qv, qi, s, x, intervals, nbrs, status,
            sem_flag=sem.flag, sem_is_filter=sem_is_filter,
            ef=ef, max_steps=steps_cap,
        )
    )
    beam_ids, beam_d, steps = run(q_v, q_int, entry_one)
    top_d, top_i = jax.lax.top_k(-beam_d, k)
    ids = jnp.take_along_axis(beam_ids, top_i, axis=-1)
    dist = -top_d
    ids = jnp.where(jnp.isfinite(dist), ids, -1)
    return SearchResult(ids, dist, steps)


def search(
    x: jnp.ndarray,
    intervals: jnp.ndarray,
    nbrs: jnp.ndarray,
    status: jnp.ndarray,
    eidx: EntryIndex,
    q_v: jnp.ndarray,
    q_int: jnp.ndarray,
    *,
    sem: iv.Semantics,
    ef: int,
    k: int,
    max_steps: int = 0,
    backend: str | None = None,
    width: int = 4,
) -> SearchResult:
    """Entry acquisition (Alg. 5) + interval-aware beam search (Alg. 4).

    The fused backends seed the beam with a ``width``-wide entry batch
    (widened Alg. 5) so the very first step already expands ``W`` nodes.
    """
    if backend == "legacy":
        entry_ids = get_entry(eidx, q_int, sem)
    else:
        entry_ids = get_entry_batch(eidx, q_int, sem, width=width)
    return beam_search(
        x, intervals, nbrs, status, entry_ids, q_v, q_int,
        sem=sem, ef=ef, k=k, max_steps=max_steps,
        backend=backend, width=width,
    )


def brute_force(
    x: jnp.ndarray,
    intervals: jnp.ndarray,
    q_v: jnp.ndarray,
    q_int: jnp.ndarray,
    *,
    sem: iv.Semantics,
    k: int,
    block: int = 8192,
) -> SearchResult:
    """Exact predicate-filtered top-k (ground truth for every benchmark)."""
    from repro.core.candidates import merge_topk

    nq = q_v.shape[0]
    n = x.shape[0]
    ids = jnp.full((nq, k), -1, jnp.int32)
    d = jnp.full((nq, k), jnp.inf, jnp.float32)
    for s in range(0, n, block):
        xb = x[s : s + block]
        ib = intervals[s : s + block]
        db = jnp.sum(
            (q_v[:, None, :].astype(jnp.float32) - xb[None, :, :].astype(jnp.float32)) ** 2,
            axis=-1,
        )
        ok = iv.predicate(sem, ib[None, :, :], q_int[:, None, :])
        db = jnp.where(ok, db, jnp.inf)
        take = min(k, xb.shape[0])
        neg, idx = jax.lax.top_k(-db, take)
        bids = jnp.arange(s, s + xb.shape[0], dtype=jnp.int32)
        bid = jnp.broadcast_to(bids[None, :], db.shape)
        ids, d = merge_topk(ids, d, jnp.take_along_axis(bid, idx, axis=-1), -neg, k)
    ids = jnp.where(jnp.isfinite(d), ids, -1)
    return SearchResult(ids, d, jnp.zeros((nq,), jnp.int32))
