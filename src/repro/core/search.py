"""Interval-aware beam search over the unified graph (paper Alg. 4).

TPU adaptation (DESIGN.md §2): the per-query priority queues of the paper
become a fixed-width ``(B, ef)`` beam advanced by a ``lax.while_loop``; the
visited hash-set becomes an exact per-query bitmap updated with one
deduplicated scatter-add per step; each expansion scores the neighbors of
the selected nodes through the expand-score kernel.

Two generations of the hot loop live here (DESIGN.md §8/§10):

* ``backend="legacy"`` — the original per-query ``vmap`` loop: one node
  expanded per step, full ``(ef + M)`` argsort per step;
* ``backend="pallas" | "xla"`` — the fused multi-expansion pipeline: the
  whole batch steps together, each step expands the ``W`` best unexpanded
  frontier nodes per query, scores all ``W·M`` neighbors through
  ``ops.expand_score`` (scalar-prefetch row gather on TPU — the
  ``(B, C, d)`` candidate tensor is never materialized), dedups candidate
  ids with the sort-based ``dedup_first`` (no ``(B, C, C)`` intermediate),
  and folds them into the sorted beam with the bitonic partial-merge kernel
  (``kernels/beam_merge.py``).  The two fused backends run identical
  networks and return bit-identical ids/dists;
  :func:`search_step_memory_profile` walks one traced step to certify the
  quadratic intermediates are gone.

Query semantics are *runtime* state (DESIGN.md §10): every query carries an
int32 sem flag (``FLAG_IF`` for IF/RF, ``FLAG_IS`` for IS/RS) and
:func:`beam_search_flags` jits one program — with no static semantics
argument — that serves a mixed IF/IS/RF/RS batch.  :func:`beam_search`
(static :class:`Semantics`) is a thin wrapper over it.

Tombstones (DESIGN.md §11): ``alive`` is an optional ``(n,)`` bool mask.
Tombstoned nodes (``alive=False``) are scored and traversed exactly like
live nodes — deleting a node must not disconnect the monotone paths that
run through it — but they are filtered at result extraction, so they can
*route* and never *surface*.  ``alive=None`` (static index) skips the
masking entirely and is bit-identical to the pre-tombstone pipeline.

IndexStore (DESIGN.md §12): the public entry points take one
:class:`repro.core.store.IndexStore` pytree instead of hand-carried
``(x, intervals, nbrs, status, alive)`` tuples.  Scoring dispatches on the
store's vector-plane tag (``ops.expand_score_plane``): ``f32``/``bf16``
run the existing kernels (rows cast in-register), ``int8`` the quantized
dequant-in-register twins.  When the store carries a rerank plane, the
final beam is re-scored against the exact f32 vectors before top-k
extraction, so a quantized scan plane keeps f32-grade answers.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import intervals as iv
from repro.core.entry import get_entry_batch_flags, get_entry_flags
from repro.kernels import ops
from repro.kernels.beam_merge import PAD_PAYLOAD, next_pow2
from repro.kernels.expand_score import dedup_first, dedup_first_quadratic


class SearchResult(NamedTuple):
    ids: jnp.ndarray    # (B, k) int32 node ids, ascending distance, -1 pad
    dist: jnp.ndarray   # (B, k) f32 squared distances (+inf pad)
    steps: jnp.ndarray  # (B,) int32 expansion count (work metric for QPS)
    # () int32 shared while_loop iterations of the fused batch (None where
    # not applicable).  On lane-parallel hardware the batch-synchronous
    # latency is iterations × per-step latency (B-independent up to the lane
    # count), so this is the hardware-independent QPS signal the mixed-
    # workload benchmark models (DESIGN.md §10) — the same role the
    # comparator count plays for the merge kernel (§8).
    iters: jnp.ndarray | None = None


def _bitmap_test(bitmap: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    word = jnp.clip(ids, 0, None) >> 5
    bit = jnp.clip(ids, 0, None) & 31
    return ((bitmap[word] >> bit) & 1).astype(bool)


def _bitmap_set(bitmap: jnp.ndarray, ids: jnp.ndarray, fresh: jnp.ndarray) -> jnp.ndarray:
    """OR the bits of ``ids[fresh]`` into the bitmap with one scatter-add.

    Neighbor lists are duplicate-free (build-time invariant) and ``fresh``
    excludes already-set bits, so add == or.
    """
    nwords = bitmap.shape[0]
    word = jnp.where(fresh, ids >> 5, nwords)  # out-of-range rows are dropped
    bit = (ids & 31).astype(jnp.uint32)
    return bitmap.at[word].add(
        jnp.where(fresh, jnp.uint32(1) << bit, jnp.uint32(0)), mode="drop"
    )


def _search_one(
    q_v: jnp.ndarray,        # (d,)
    q_int: jnp.ndarray,      # (2,)
    start: jnp.ndarray,      # () int32, -1 = no valid entry
    sem_flag: jnp.ndarray,   # () int32 FLAG_IF | FLAG_IS (runtime semantics)
    plane,                   # VectorPlane — the (n, d) scoring plane
    intervals: jnp.ndarray,  # (n, 2)
    nbrs: jnp.ndarray,       # (n, M)
    status: jnp.ndarray,     # (n, M) uint8
    ef: int,
    max_steps: int,
):
    n, d = plane.data.shape
    M = nbrs.shape[1]
    nwords = (n + 31) // 32

    q32 = q_v.astype(jnp.float32)

    def dist_to(ids):
        xs = plane.decode_rows(jnp.clip(ids, 0, n - 1)).astype(jnp.float32)
        diff = xs - q32[None, :]
        return jnp.sum(diff * diff, axis=-1)

    has_entry = start >= 0
    start_c = jnp.clip(start, 0, n - 1)

    beam_ids = jnp.full((ef,), -1, jnp.int32).at[0].set(jnp.where(has_entry, start_c, -1))
    beam_d = jnp.full((ef,), jnp.inf, jnp.float32).at[0].set(
        jnp.where(has_entry, dist_to(start_c[None])[0], jnp.inf)
    )
    expanded = jnp.zeros((ef,), bool)
    visited = jnp.zeros((nwords,), jnp.uint32)
    visited = _bitmap_set(visited, start_c[None], has_entry[None])

    def predicate(obj_int):
        return iv.predicate_by_flag(sem_flag, obj_int, q_int[None, :])

    def cond(state):
        beam_ids, beam_d, expanded, visited, steps = state
        frontier = (~expanded) & jnp.isfinite(beam_d)
        return jnp.any(frontier) & (steps < max_steps)

    def body(state):
        beam_ids, beam_d, expanded, visited, steps = state
        # ExtractMin over unexpanded beam entries (Alg. 4 line 6).
        sel_d = jnp.where(expanded, jnp.inf, beam_d)
        j = jnp.argmin(sel_d)
        u = beam_ids[j]
        expanded = expanded.at[j].set(True)
        u_c = jnp.clip(u, 0, n - 1)

        nb = nbrs[u_c]                      # (M,)
        st = status[u_c]
        present = nb >= 0
        nb_c = jnp.clip(nb, 0, n - 1)
        seen = _bitmap_test(visited, nb_c) | ~present

        sem_ok = (st.astype(jnp.int32) & sem_flag) > 0
        pred_ok = predicate(intervals[nb_c])
        valid = present & ~seen & sem_ok & pred_ok
        # Visited semantics follow the σ-projection G^σ the theory searches
        # (Thm 3.3): mark nodes that were scored (valid) or are node-level
        # dead for this query (predicate fails — can never become valid), but
        # NOT nodes skipped only because *this* edge's σ-bit is off: they may
        # be reachable via another σ-active edge.  (Deviation from Alg. 4's
        # literal line 10; see DESIGN.md §6.)
        visited = _bitmap_set(visited, nb_c, present & ~seen & (valid | ~pred_ok))
        nd = jnp.where(valid, dist_to(nb_c), jnp.inf)

        # Merge candidates into the beam; keep ef best (RemoveMax of Alg. 4).
        all_ids = jnp.concatenate([beam_ids, jnp.where(valid, nb_c, -1)])
        all_d = jnp.concatenate([beam_d, nd])
        all_exp = jnp.concatenate([expanded, jnp.zeros((M,), bool)])
        order = jnp.argsort(all_d)[:ef]
        return (
            all_ids[order],
            all_d[order],
            all_exp[order],
            visited,
            steps + 1,
        )

    state = (beam_ids, beam_d, expanded, visited, jnp.int32(0))
    beam_ids, beam_d, expanded, visited, steps = jax.lax.while_loop(cond, body, state)
    return beam_ids, beam_d, steps


def _make_fused_step(
    plane,                   # VectorPlane — the (n, d) scoring plane
    intervals: jnp.ndarray,  # (n, 2)
    nbrs: jnp.ndarray,       # (n, M)
    status: jnp.ndarray,     # (n, M) uint8
    q32: jnp.ndarray,        # (B, d) f32
    q_int: jnp.ndarray,      # (B, 2)
    sem_flags: jnp.ndarray,  # (B,) int32 runtime semantics
    *,
    W: int,
    backend: str,
):
    """Build ``(step, score, merge)`` for the fused hot loop (§8/§10).

    ``step`` advances ``(beam_d, beam_p, visited, steps)`` by one fused
    multi-expansion; it is also what :func:`search_step_memory_profile`
    traces, so the profiled program *is* the served program.  With
    ``backend="legacy"`` the step runs the pre-fusion expand/dedup pair —
    ``(B, C, d)`` gather + matmul and the ``O(C²)`` pairwise dedup — kept
    only as the A/B baseline for that profile.
    """
    n, d = plane.data.shape
    M = nbrs.shape[1]
    B = q32.shape[0]
    C = W * M

    bitmap_test = jax.vmap(_bitmap_test)
    bitmap_set = jax.vmap(_bitmap_set)
    rowi = jnp.arange(B, dtype=jnp.int32)[:, None]
    # The partial merge has no legacy variant; the legacy expand/dedup
    # profile reuses the xla merge network.
    merge_backend = "xla" if backend == "legacy" else backend
    dedup = dedup_first_quadratic if backend == "legacy" else dedup_first
    # PQ plane: the per-query (m, 256) distance tables are built HERE — once
    # per batch, before the while_loop traces — so every fused step reuses
    # one loop-invariant LUT instead of rebuilding it per step (None for
    # non-pq planes).
    lut = ops.pq_lut(plane, q32)

    def score(ids_c, valid):
        """Squared distances of the masked candidate ids via the
        expand-score kernel on the store's plane (+inf where invalid)."""
        return ops.expand_score_plane(
            plane, jnp.where(valid, ids_c, -1), q32, backend=backend, lut=lut
        )

    def predicate(obj_int):
        return iv.predicate_by_flag(sem_flags[:, None], obj_int, q_int[:, None, :])

    def merge(beam_d, beam_p, cand_d, cand_p):
        return ops.beam_merge(beam_d, beam_p, cand_d, cand_p, backend=merge_backend)

    def step(beam_d, beam_p, visited, steps):
        # ExtractMin_W: beam is sorted, so top_k picks the W best unexpanded.
        sel_d = jnp.where((beam_p & 1) == 0, beam_d, jnp.inf)
        neg, sel_idx = jax.lax.top_k(-sel_d, W)            # (B, W)
        sel_ok = jnp.isfinite(-neg)
        u = jnp.take_along_axis(beam_p >> 1, sel_idx, axis=-1)
        mark = jnp.zeros(beam_p.shape, jnp.int32).at[rowi, sel_idx].max(
            sel_ok.astype(jnp.int32)
        )
        beam_p = beam_p | mark

        u_c = jnp.clip(u, 0, n - 1)
        nb = jnp.where(sel_ok[..., None], nbrs[u_c], -1).reshape(B, C)
        st = status[u_c].reshape(B, C)
        present = nb >= 0
        nb_c = jnp.clip(nb, 0, n - 1)
        seen = bitmap_test(visited, nb_c) | ~present

        sem_ok = (st.astype(jnp.int32) & sem_flags[:, None]) > 0
        pred_ok = predicate(intervals[nb_c])
        cand_ok = present & ~seen & sem_ok & pred_ok
        # Same visited semantics as the legacy path (DESIGN.md §6): mark
        # scored and node-dead candidates, never edge-masked ones.  Across
        # the W lists one id may repeat — score/mark only its first
        # *eligible* occurrence so the scatter-add stays an OR.
        valid = dedup(nb_c, cand_ok)
        to_mark = dedup(nb_c, present & ~seen & (cand_ok | ~pred_ok))
        visited = bitmap_set(visited, nb_c, to_mark)

        cand_d = score(nb_c, valid)
        cand_p = jnp.where(valid, nb_c << 1, PAD_PAYLOAD)
        beam_d, beam_p = merge(beam_d, beam_p, cand_d, cand_p)
        steps = steps + jnp.sum(sel_ok, axis=-1, dtype=jnp.int32)
        return beam_d, beam_p, visited, steps

    return step, score, merge


def _beam_search_fused(
    plane,                   # VectorPlane — (n, d) scoring plane
    rerank,                  # VectorPlane | None — exact f32 re-scoring plane
    intervals: jnp.ndarray,  # (n, 2)
    nbrs: jnp.ndarray,       # (n, M)
    status: jnp.ndarray,     # (n, M) uint8
    entry_ids: jnp.ndarray,  # (B, We) int32, -1 padded
    q_v: jnp.ndarray,        # (B, d)
    q_int: jnp.ndarray,      # (B, 2)
    sem_flags: jnp.ndarray,  # (B,) int32
    alive: jnp.ndarray | None,  # (n,) bool tombstone mask (None = all live)
    *,
    ef: int,
    k: int,
    max_steps: int,
    width: int,
    backend: str,
) -> SearchResult:
    """Fused multi-expansion Alg. 4 (DESIGN.md §8).

    The beam is ``E = next_pow2(ef)`` wide (padded with ``+inf``/``-1``) and
    kept ascending under the total order ``(dist, payload)``; each payload
    packs ``id << 1 | expanded``.  Every step the ``W`` best unexpanded
    entries are expanded at once; rows whose frontier is exhausted are
    natural no-ops, so the batch shares one ``while_loop`` — and because
    every per-row quantity (distances, dedup, merge, bitmap) is computed
    row-independently, each row's result is bitwise independent of the rest
    of the batch, which is what makes mixed-semantics batches return exactly
    the per-semantics answers (DESIGN.md §10).

    With a rerank plane the (possibly quantized) scan distances steer the
    traversal only; the surviving beam is re-scored against the exact f32
    plane — ``E`` row fetches per query, once — before top-k extraction.
    """
    n, d = plane.data.shape
    B = q_v.shape[0]
    W = max(min(width, ef), 1)
    E = next_pow2(ef)
    nwords = (n + 31) // 32

    q32 = q_v.astype(jnp.float32)
    step, score, merge = _make_fused_step(
        plane, intervals, nbrs, status, q32, q_int, sem_flags,
        W=W, backend=backend,
    )

    # ---- seed: merge the (deduped) entry batch into an empty beam
    ent_valid = entry_ids >= 0
    ent_c = jnp.clip(entry_ids, 0, n - 1)
    ent_d = score(ent_c, ent_valid)
    ent_p = jnp.where(ent_valid, ent_c << 1, PAD_PAYLOAD)
    beam_d = jnp.full((B, E), jnp.inf, jnp.float32)
    beam_p = jnp.full((B, E), PAD_PAYLOAD, jnp.int32)
    beam_d, beam_p = merge(beam_d, beam_p, ent_d, ent_p)
    visited = jax.vmap(_bitmap_set)(
        jnp.zeros((B, nwords), jnp.uint32), ent_c, ent_valid
    )

    iters_cap = (max_steps + W - 1) // W

    def cond(state):
        beam_d, beam_p, visited, steps, it = state
        frontier = ((beam_p & 1) == 0) & jnp.isfinite(beam_d)
        return jnp.any(frontier) & (it < iters_cap)

    def body(state):
        beam_d, beam_p, visited, steps, it = state
        beam_d, beam_p, visited, steps = step(beam_d, beam_p, visited, steps)
        return beam_d, beam_p, visited, steps, it + 1

    state = (beam_d, beam_p, visited, jnp.zeros((B,), jnp.int32), jnp.int32(0))
    beam_d, beam_p, visited, steps, it = jax.lax.while_loop(cond, body, state)

    if rerank is not None:
        # Re-score the surviving beam against the exact f32 plane (one row
        # fetch per beam slot); the re-scored beam is no longer sorted, so
        # extraction always goes through the masked top-k.
        all_ids = beam_p >> 1
        ok = jnp.isfinite(beam_d)
        beam_d = ops.expand_score(
            rerank.data, jnp.where(ok, all_ids, -1), q32, backend=backend
        )
        if alive is not None:
            ok = ok & alive[jnp.clip(all_ids, 0, n - 1)]
        neg, sel = jax.lax.top_k(-jnp.where(ok, beam_d, jnp.inf), k)
        dist = -neg
        ids = jnp.where(
            jnp.isfinite(dist), jnp.take_along_axis(all_ids, sel, axis=-1), -1
        )
        return SearchResult(ids, dist, steps, it)
    if alive is None:
        dist = beam_d[:, :k]                               # beam is sorted
        ids = jnp.where(jnp.isfinite(dist), beam_p[:, :k] >> 1, -1)
        return SearchResult(ids, dist, steps, it)
    # Tombstone extraction: dead beam entries routed during the loop but must
    # never surface.  The beam is sorted ascending and top_k breaks ties by
    # position, so with an all-live mask this selects exactly beam[:, :k]
    # (bit-identical to the static-index path).
    all_ids = beam_p >> 1
    ok = jnp.isfinite(beam_d) & alive[jnp.clip(all_ids, 0, n - 1)]
    neg, sel = jax.lax.top_k(-jnp.where(ok, beam_d, jnp.inf), k)
    dist = -neg
    ids = jnp.where(
        jnp.isfinite(dist), jnp.take_along_axis(all_ids, sel, axis=-1), -1
    )
    return SearchResult(ids, dist, steps, it)


@functools.partial(
    jax.jit, static_argnames=("ef", "k", "max_steps", "backend", "width")
)
def _beam_search_flags_impl(
    plane,                    # VectorPlane scoring plane
    rerank,                   # VectorPlane | None exact f32 plane
    intervals: jnp.ndarray,
    nbrs: jnp.ndarray,
    status: jnp.ndarray,
    alive: jnp.ndarray | None,
    entry_ids: jnp.ndarray,   # (B,) or (B, We) int32 entry node(s) (Alg. 5)
    q_v: jnp.ndarray,         # (B, d)
    q_int: jnp.ndarray,       # (B, 2)
    sem_flags: jnp.ndarray,   # (B,) int32 runtime semantics (FLAG_IF/FLAG_IS)
    *,
    ef: int,
    k: int,
    max_steps: int = 0,
    backend: str | None = None,
    width: int = 4,
) -> SearchResult:
    steps_cap = max_steps if max_steps > 0 else 8 * ef + 32
    sem_flags = sem_flags.astype(jnp.int32)
    if backend != "legacy":
        backend = ops.resolve_backend(backend)
        ent = entry_ids[:, None] if entry_ids.ndim == 1 else entry_ids
        return _beam_search_fused(
            plane, rerank, intervals, nbrs, status, ent, q_v, q_int,
            sem_flags, alive,
            ef=ef, k=k, max_steps=steps_cap, width=width, backend=backend,
        )
    entry_one = entry_ids if entry_ids.ndim == 1 else entry_ids[:, 0]
    run = jax.vmap(
        lambda qv, qi, s, f: _search_one(
            qv, qi, s, f, plane, intervals, nbrs, status,
            ef=ef, max_steps=steps_cap,
        )
    )
    beam_ids, beam_d, steps = run(q_v, q_int, entry_one, sem_flags)
    n = plane.data.shape[0]
    if rerank is not None:  # exact-plane re-scoring of the surviving beam
        ok = jnp.isfinite(beam_d) & (beam_ids >= 0)
        beam_d = ops.expand_score(
            rerank.data, jnp.where(ok, beam_ids, -1),
            q_v.astype(jnp.float32), backend=None,
        )
    if alive is not None:  # tombstoned beam entries never surface
        beam_d = jnp.where(
            (beam_ids >= 0) & alive[jnp.clip(beam_ids, 0, n - 1)],
            beam_d, jnp.inf,
        )
    top_d, top_i = jax.lax.top_k(-beam_d, k)
    ids = jnp.take_along_axis(beam_ids, top_i, axis=-1)
    dist = -top_d
    ids = jnp.where(jnp.isfinite(dist), ids, -1)
    # legacy expands one node per per-row loop step: the synchronous-batch
    # iteration equivalent is the slowest row's step count.
    return SearchResult(ids, dist, steps, jnp.max(steps))


def beam_search_flags(
    store,
    entry_ids: jnp.ndarray,   # (B,) or (B, We) int32 entry node(s) (Alg. 5)
    q_v: jnp.ndarray,         # (B, d)
    q_int: jnp.ndarray,       # (B, 2)
    sem_flags: jnp.ndarray,   # (B,) int32 runtime semantics (FLAG_IF/FLAG_IS)
    *,
    ef: int,
    k: int,
    max_steps: int = 0,
    backend: str | None = None,
    width: int = 4,
) -> SearchResult:
    """Batched Alg. 4 with *runtime* per-query semantics (DESIGN.md §10)
    over an :class:`~repro.core.store.IndexStore`.

    ``sem_flags`` is a traced ``(B,)`` array — not a static argname — so one
    compiled program serves a mixed IF/IS/RF/RS batch; ``max_steps=0``
    derives a generous default (8·ef+32).  ``backend`` selects the hot-loop
    implementation: ``"pallas"`` / ``"xla"`` are the fused multi-expansion
    pipeline (bit-identical to each other; default — pallas on TPU, xla on
    CPU), ``"legacy"`` the original one-node-per-step argsort loop.
    ``width`` is the fused frontier width W.  The store's ``alive`` mask is
    the tombstone mask (DESIGN.md §11): dead nodes route but never surface;
    its plane tag picks the scoring kernel and its rerank plane (when
    present) re-scores the final beam (DESIGN.md §12).  The store's entry
    structure is *not* consulted — entry ids come from the caller.
    """
    return _beam_search_flags_impl(
        store.plane, store.rerank, store.intervals, store.nbrs, store.status,
        store.alive, entry_ids, q_v, q_int, sem_flags,
        ef=ef, k=k, max_steps=max_steps, backend=backend, width=width,
    )


def beam_search(
    store,
    entry_ids: jnp.ndarray,
    q_v: jnp.ndarray,
    q_int: jnp.ndarray,
    *,
    sem: iv.Semantics,
    ef: int,
    k: int,
    max_steps: int = 0,
    backend: str | None = None,
    width: int = 4,
) -> SearchResult:
    """Single-semantics Alg. 4: a thin wrapper that broadcasts ``sem`` to a
    flag array and runs the same compiled program as the mixed path."""
    return beam_search_flags(
        store, entry_ids, q_v, q_int, iv.as_sem_flags(sem, q_v.shape[0]),
        ef=ef, k=k, max_steps=max_steps, backend=backend, width=width,
    )


def search_mixed(
    store,
    q_v: jnp.ndarray,
    q_int: jnp.ndarray,
    sem_flags,
    *,
    ef: int,
    k: int,
    max_steps: int = 0,
    backend: str | None = None,
    width: int = 4,
) -> SearchResult:
    """Entry acquisition (Alg. 5) + beam search (Alg. 4) for a batch whose
    queries each carry their own semantics (DESIGN.md §10).

    ``sem_flags`` accepts anything :func:`intervals.as_sem_flags` does: one
    :class:`Semantics`, a per-query sequence, or a ``(B,)`` flag array.
    The store must carry an entry structure built with a ``node_mask``
    matching its ``alive`` mask so Alg. 5 never certifies a dead node
    (UGIndex.delete maintains that invariant).
    """
    eidx = store.entry
    if eidx is None:
        raise ValueError(
            "store has no entry structure; build one (make_store/"
            "build_entry_index) or pass entry ids to beam_search_flags")
    flags = iv.as_sem_flags(sem_flags, q_v.shape[0])
    if backend == "legacy":
        entry_ids = get_entry_flags(eidx, q_int, flags)
    else:
        entry_ids = get_entry_batch_flags(eidx, q_int, flags, width=width)
    return beam_search_flags(
        store, entry_ids, q_v, q_int, flags,
        ef=ef, k=k, max_steps=max_steps, backend=backend, width=width,
    )


def search(
    store,
    q_v: jnp.ndarray,
    q_int: jnp.ndarray,
    *,
    sem: iv.Semantics,
    ef: int,
    k: int,
    max_steps: int = 0,
    backend: str | None = None,
    width: int = 4,
) -> SearchResult:
    """Entry acquisition (Alg. 5) + interval-aware beam search (Alg. 4).

    The fused backends seed the beam with a ``width``-wide entry batch
    (widened Alg. 5) so the very first step already expands ``W`` nodes.
    """
    return search_mixed(
        store, q_v, q_int, sem,
        ef=ef, k=k, max_steps=max_steps, backend=backend, width=width,
    )


# ------------------------------------------------------------ memory profile
def search_step_memory_profile(
    backend: str,
    *,
    B: int = 8,
    n: int = 2048,
    d: int = 24,
    M: int = 16,
    width: int = 4,
    ef: int = 32,
    dtype: str = "f32",
) -> dict:
    """Trace one fused search step and report its intermediate profile.

    Returns ``{"peak_bytes", "gather_bcd", "quadratic_cc", "decoded_nd"}`` —
    whether any ``(B, C, d)`` candidate gather, ``(·, C, C)`` dedup tensor,
    or decoded ``(n, d)`` f32 corpus is materialized.  The new path
    (``xla``/``pallas``) must show none of them; the ``legacy``
    expand/dedup baseline shows the first two (the ISSUE-3 acceptance
    check, mirroring PR 2's ``sweep_memory_profile``).  ``dtype`` selects
    the vector plane: the quantized kernels carry the identical guarantee
    (DESIGN.md §12), which this profile certifies for ``int8`` too, and the
    ``pq`` LUT kernels additionally certify that scoring never decodes the
    corpus (``decoded_nd`` — only the legacy pq baseline does, DESIGN.md
    §14).
    """
    from repro.core.store import VectorPlane, default_pq_m, PQ_K
    from repro.kernels.prune_sweep import _iter_eqn_avals

    C = max(min(width, ef), 1) * M
    E = next_pow2(ef)
    nwords = (n + 31) // 32
    f32, i32 = jnp.float32, jnp.int32

    def one_step(plane, intervals, nbrs, status, q_v, q_int, sem_flags,
                 beam_d, beam_p, visited, steps):
        step, _, _ = _make_fused_step(
            plane, intervals, nbrs, status, q_v.astype(f32), q_int, sem_flags,
            W=max(min(width, ef), 1), backend=backend,
        )
        return step(beam_d, beam_p, visited, steps)

    if dtype == "int8":
        plane_sds = VectorPlane(
            "int8", jax.ShapeDtypeStruct((n, d), jnp.int8),
            jax.ShapeDtypeStruct((d,), f32), jax.ShapeDtypeStruct((d,), f32),
        )
    elif dtype == "pq":
        m = default_pq_m(d)
        plane_sds = VectorPlane(
            "pq", jax.ShapeDtypeStruct((n, m), jnp.uint8),
            codebooks=jax.ShapeDtypeStruct((m, PQ_K, d // m), f32),
        )
    else:
        plane_dt = {"f32": jnp.float32, "bf16": jnp.bfloat16}[dtype]
        plane_sds = VectorPlane(dtype, jax.ShapeDtypeStruct((n, d), plane_dt))
    args = (
        plane_sds,
        jax.ShapeDtypeStruct((n, 2), f32),
        jax.ShapeDtypeStruct((n, M), i32),
        jax.ShapeDtypeStruct((n, M), jnp.uint8),
        jax.ShapeDtypeStruct((B, d), f32),
        jax.ShapeDtypeStruct((B, 2), f32),
        jax.ShapeDtypeStruct((B,), i32),
        jax.ShapeDtypeStruct((B, E), f32),
        jax.ShapeDtypeStruct((B, E), i32),
        jax.ShapeDtypeStruct((B, nwords), jnp.uint32),
        jax.ShapeDtypeStruct((B,), i32),
    )
    closed = jax.make_jaxpr(one_step)(*args)
    peak = 0
    gather_bcd = False
    quadratic = False
    decoded_nd = False
    for aval in _iter_eqn_avals(closed.jaxpr):
        size = int(aval.size) * aval.dtype.itemsize if aval.shape else aval.dtype.itemsize
        peak = max(peak, size)
        if len(aval.shape) >= 3 and aval.shape[-2:] == (C, d):
            gather_bcd = True
        if len(aval.shape) >= 2 and aval.shape[-2:] == (C, C):
            quadratic = True
        if len(aval.shape) >= 2 and aval.shape[-2:] == (n, d) \
                and aval.dtype == jnp.float32:
            decoded_nd = True
    return {
        "peak_bytes": peak,
        "gather_bcd": gather_bcd,
        "quadratic_cc": quadratic,
        "decoded_nd": decoded_nd,
    }


# ----------------------------------------------------------------- exact
@functools.partial(jax.jit, static_argnames=("is_filter", "k"))
def _brute_force_block(xb, ib, mb, q32, qn, q_int, ids, d, start, *, is_filter, k):
    """One jitted ground-truth block step: matmul-identity distances
    (``‖x‖²+‖q‖²−2·x·q`` — no ``(nq, block, d)`` diff tensor), predicate
    mask, exact block top-k, fold into the running top-k.  ``mb`` is the
    block's alive mask (tombstoned/free slots never enter the truth set)."""
    from repro.core.candidates import merge_topk

    xb32 = xb.astype(jnp.float32)
    xn = jnp.sum(xb32 * xb32, axis=-1)
    ip = q32 @ xb32.T
    db = jnp.maximum(qn[:, None] + xn[None, :] - 2.0 * ip, 0.0)
    if is_filter:
        ok = iv.contains(q_int[:, None, :], ib[None, :, :])
    else:
        ok = iv.contains(ib[None, :, :], q_int[:, None, :])
    db = jnp.where(ok & mb[None, :], db, jnp.inf)
    take = min(k, xb.shape[0])
    neg, idx = jax.lax.top_k(-db, take)
    bids = start + idx.astype(jnp.int32)
    return merge_topk(ids, d, bids, -neg, k)


def brute_force(
    x: jnp.ndarray,
    intervals: jnp.ndarray,
    q_v: jnp.ndarray,
    q_int: jnp.ndarray,
    *,
    sem: iv.Semantics,
    k: int,
    block: int = 8192,
    alive: jnp.ndarray | None = None,
) -> SearchResult:
    """Exact predicate-filtered top-k (ground truth for every benchmark).

    The per-block step is jitted once per block shape (full blocks share one
    program, the remainder block at most one more) and uses the matmul
    identity, so the harness's dominant cost at scale is one ``(nq, block)``
    GEMM per block instead of an untraced ``(nq, block, d)`` diff tensor.
    ``alive`` restricts the truth set to live nodes (DESIGN.md §11).
    """
    nq = q_v.shape[0]
    n = x.shape[0]
    q32 = q_v.astype(jnp.float32)
    qn = jnp.sum(q32 * q32, axis=-1)
    if alive is None:
        alive = jnp.ones((n,), bool)
    is_filter = sem in (iv.Semantics.IF, iv.Semantics.RF)
    ids = jnp.full((nq, k), -1, jnp.int32)
    d = jnp.full((nq, k), jnp.inf, jnp.float32)
    for s in range(0, n, block):
        ids, d = _brute_force_block(
            x[s : s + block], intervals[s : s + block], alive[s : s + block],
            q32, qn, q_int, ids, d, jnp.int32(s), is_filter=is_filter, k=k,
        )
    ids = jnp.where(jnp.isfinite(d), ids, -1)
    return SearchResult(ids, d, jnp.zeros((nq,), jnp.int32))
