"""Entry-node acquisition (paper Alg. 5, Lemma 4.3).

Nodes are sorted by interval left endpoint; two auxiliary arrays — the suffix
minimum and prefix maximum of right endpoints (with arg-indices) — let a valid
entry node be found in O(log n) for both IF and IS queries, or NULL certified
when no valid node exists.

Built with ``jax.lax.associative_scan`` so the structure is jittable and can
be constructed per shard inside ``shard_map`` (each index shard owns its own
entry arrays; see DESIGN.md §4).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import intervals as iv


class EntryIndex(NamedTuple):
    node_id: jnp.ndarray        # (n,) int32 — node ids sorted by left endpoint
    l_sorted: jnp.ndarray       # (n,) f32   — sorted left endpoints
    suffmin_r_val: jnp.ndarray  # (n,) f32   — min right endpoint over suffix
    suffmin_r_id: jnp.ndarray   # (n,) int32 — arg node id of that minimum
    prefmax_r_val: jnp.ndarray  # (n,) f32   — max right endpoint over prefix
    prefmax_r_id: jnp.ndarray   # (n,) int32 — arg node id of that maximum


def _argscan(vals: jnp.ndarray, ids: jnp.ndarray, op: str, reverse: bool):
    """Associative scan carrying (value, arg-id) pairs."""

    def combine(a, b):
        av, ai = a
        bv, bi = b
        if op == "min":
            take_b = bv < av
        else:
            take_b = bv > av
        return jnp.where(take_b, bv, av), jnp.where(take_b, bi, ai)

    return jax.lax.associative_scan(combine, (vals, ids), reverse=reverse)


def build_entry_index(
    intervals: jnp.ndarray, node_mask: jnp.ndarray | None = None
) -> EntryIndex:
    """Sort by left endpoint and precompute suffix-min / prefix-max of rights.

    ``node_mask`` excludes nodes (masked rows get ``l=+inf`` so they sort last
    and sentinel rights so they never win a scan) — used for per-shard or
    filtered sub-index entry structures.
    """
    n = intervals.shape[0]
    l = intervals[:, 0].astype(jnp.float32)
    r = intervals[:, 1].astype(jnp.float32)
    if node_mask is not None:
        l = jnp.where(node_mask, l, jnp.inf)
        r_for_min = jnp.where(node_mask, r, jnp.inf)
        r_for_max = jnp.where(node_mask, r, -jnp.inf)
    else:
        r_for_min = r
        r_for_max = r
    order = jnp.argsort(l, stable=True).astype(jnp.int32)
    l_s = l[order]
    rmin_s = r_for_min[order]
    rmax_s = r_for_max[order]
    sv, si = _argscan(rmin_s, order, "min", reverse=True)
    pv, pi = _argscan(rmax_s, order, "max", reverse=False)
    return EntryIndex(order, l_s, sv, si, pv, pi)


def _entry_if(eidx: EntryIndex, ql: jnp.ndarray, qr: jnp.ndarray) -> jnp.ndarray:
    """IF/RF branch of Alg. 5: first position with ``l ≥ q.l``, suffix-min
    right endpoint certifies a valid node or NULL (Lemma 4.3)."""
    n = eidx.l_sorted.shape[0]
    i = jnp.searchsorted(eidx.l_sorted, ql, side="left")
    ok = i < n
    ic = jnp.clip(i, 0, n - 1)
    ok = ok & (eidx.suffmin_r_val[ic] <= qr)
    return jnp.where(ok, eidx.suffmin_r_id[ic], -1).astype(jnp.int32)


def _entry_is(eidx: EntryIndex, ql: jnp.ndarray, qr: jnp.ndarray) -> jnp.ndarray:
    """IS/RS branch of Alg. 5 (dual: prefix-max over ``l ≤ q.l``)."""
    n = eidx.l_sorted.shape[0]
    i = jnp.searchsorted(eidx.l_sorted, ql, side="right") - 1
    ok = i >= 0
    ic = jnp.clip(i, 0, n - 1)
    ok = ok & (eidx.prefmax_r_val[ic] >= qr)
    return jnp.where(ok, eidx.prefmax_r_id[ic], -1).astype(jnp.int32)


def get_entry_flags(
    eidx: EntryIndex, q_interval: jnp.ndarray, sem_flags: jnp.ndarray
) -> jnp.ndarray:
    """Alg. 5 with runtime per-query semantics: ``sem_flags`` (…,) int32
    selects the IF or IS branch per query, so one compiled program serves a
    mixed batch.  Each selected lane is computed exactly as the static path
    computes it (bitwise-equal results)."""
    ql = q_interval[..., 0]
    qr = q_interval[..., 1]
    return jnp.where(
        iv.is_filter_flag(sem_flags), _entry_if(eidx, ql, qr), _entry_is(eidx, ql, qr)
    ).astype(jnp.int32)


def get_entry(
    eidx: EntryIndex, q_interval: jnp.ndarray, sem: iv.Semantics
) -> jnp.ndarray:
    """Alg. 5 for a batch of query intervals (..., 2) -> (...,) int32 ids.

    Returns -1 when no valid node exists (the NULL case of Lemma 4.3).
    RF == IF and RS == IS after degenerate-interval reduction (§2.1).
    """
    ql = q_interval[..., 0]
    qr = q_interval[..., 1]
    if sem in (iv.Semantics.IF, iv.Semantics.RF):
        return _entry_if(eidx, ql, qr)
    return _entry_is(eidx, ql, qr)


def get_entry_batch(
    eidx: EntryIndex, q_interval: jnp.ndarray, sem: iv.Semantics, width: int = 1
) -> jnp.ndarray:
    """Widened Alg. 5: up to ``width`` *distinct* valid entries per query.

    The multi-expansion search (DESIGN.md §8) seeds its initial frontier with
    several entry nodes so the first fused step already expands ``W`` nodes.
    Lemma 4.3 generalizes position-wise: for an IF query, *every* position
    ``p ≥ i`` of the left-endpoint order whose suffix-min right endpoint is
    ``≤ q.r`` certifies a valid entry (that arg node has ``l ≥ l_sorted[p] ≥
    q.l``); dually for IS with the prefix-max over ``p ≤ i``.  Adjacent
    positions often share an arg node, so duplicates are masked to ``-1``
    (first occurrence kept).  Column 0 equals :func:`get_entry` exactly.

    Returns (..., width) int32, ``-1``-padded.
    """
    if sem in (iv.Semantics.IF, iv.Semantics.RF):
        ids = _entry_batch_if(eidx, q_interval, max(int(width), 1))
    else:
        ids = _entry_batch_is(eidx, q_interval, max(int(width), 1))
    return _mask_duplicate_entries(ids)


def get_entry_batch_flags(
    eidx: EntryIndex, q_interval: jnp.ndarray, sem_flags: jnp.ndarray, width: int = 1
) -> jnp.ndarray:
    """Widened Alg. 5 with runtime per-query semantics ((…,) int32 flags).

    Computes both branch position walks and selects per query, then masks
    duplicates exactly as :func:`get_entry_batch` — a uniform-flag batch is
    bitwise equal to the static call, so the mixed-workload search path can
    share one compiled entry program (DESIGN.md §10).
    """
    width = max(int(width), 1)
    ids = jnp.where(
        iv.is_filter_flag(sem_flags)[..., None],
        _entry_batch_if(eidx, q_interval, width),
        _entry_batch_is(eidx, q_interval, width),
    )
    return _mask_duplicate_entries(ids)


def _entry_batch_if(eidx: EntryIndex, q_interval: jnp.ndarray, width: int) -> jnp.ndarray:
    n = eidx.l_sorted.shape[0]
    ql = q_interval[..., 0]
    qr = q_interval[..., 1]
    offs = jnp.arange(width, dtype=jnp.int32)
    i = jnp.searchsorted(eidx.l_sorted, ql, side="left")
    pos = i[..., None] + offs
    ok = pos < n
    pc = jnp.clip(pos, 0, n - 1)
    ok = ok & (eidx.suffmin_r_val[pc] <= qr[..., None])
    return jnp.where(ok, eidx.suffmin_r_id[pc], -1)


def _entry_batch_is(eidx: EntryIndex, q_interval: jnp.ndarray, width: int) -> jnp.ndarray:
    n = eidx.l_sorted.shape[0]
    ql = q_interval[..., 0]
    qr = q_interval[..., 1]
    offs = jnp.arange(width, dtype=jnp.int32)
    i = jnp.searchsorted(eidx.l_sorted, ql, side="right") - 1
    pos = i[..., None] - offs
    ok = pos >= 0
    pc = jnp.clip(pos, 0, n - 1)
    ok = ok & (eidx.prefmax_r_val[pc] >= qr[..., None])
    return jnp.where(ok, eidx.prefmax_r_id[pc], -1)


def _mask_duplicate_entries(ids: jnp.ndarray) -> jnp.ndarray:
    """Mask repeated arg nodes to -1, first occurrence kept (width is small,
    so the O(width²) pairwise mask is fine here)."""
    width = ids.shape[-1]
    offs = jnp.arange(width, dtype=jnp.int32)
    dup = (ids[..., :, None] == ids[..., None, :]) & (ids[..., None, :] >= 0)
    earlier = offs[:, None] > offs[None, :]
    return jnp.where(jnp.any(dup & earlier, axis=-1), -1, ids).astype(jnp.int32)
