"""Initial candidate generation for UG (paper Alg. 1).

Two complementary sources, exactly as the paper prescribes:

* **spatial** candidates from NN-descent with budget ``ef_spatial`` — the
  navigational backbone;
* **attribute** candidates from the four interval-derived sort keys
  ``{l, r, mid, len}``, taking ``ef_attribute / 8`` adjacent nodes per side
  per key — likely IF/IS witnesses under interval constraints.

The NN-descent here is a TPU-style reformulation: fixed-width neighbor
tensors, the local join expressed as blocked gathers + matmul distances, and
reverse edges recovered with a sort/segment-rank scatter (no dynamic lists).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.prune import squared_dist
from repro.kernels.util import pad_rows, pad_to, segment_scatter


class KnnState(NamedTuple):
    ids: jnp.ndarray    # (n, K) int32 neighbor ids, ascending distance, -1 pad
    dist: jnp.ndarray   # (n, K) f32 squared distances (+inf pad)


def merge_topk(ids_a, d_a, ids_b, d_b, k: int):
    """Merge two candidate lists per row, dedup ids, keep the k closest."""
    ids = jnp.concatenate([ids_a, ids_b], axis=-1)
    d = jnp.concatenate([d_a, d_b], axis=-1)
    d = jnp.where(ids < 0, jnp.inf, d)
    # Dedup: sort by id, mask repeats, undo permutation.
    io = jnp.argsort(ids, axis=-1)
    si = jnp.take_along_axis(ids, io, axis=-1)
    dup_sorted = jnp.concatenate(
        [jnp.zeros_like(si[..., :1], bool), (si[..., 1:] == si[..., :-1]) & (si[..., 1:] >= 0)],
        axis=-1,
    )
    dup = jnp.zeros_like(dup_sorted)
    dup = jnp.put_along_axis(dup, io, dup_sorted, axis=-1, inplace=False)
    d = jnp.where(dup, jnp.inf, d)
    order = jnp.argsort(d, axis=-1)[..., :k]
    out_ids = jnp.take_along_axis(ids, order, axis=-1)
    out_d = jnp.take_along_axis(d, order, axis=-1)
    out_ids = jnp.where(jnp.isfinite(out_d), out_ids, -1)
    return out_ids, out_d


def _block_knn_scan(x: jnp.ndarray, queries: jnp.ndarray, k: int, block: int = 4096):
    """Exact top-k of ``queries`` against corpus ``x`` by streaming blocks."""
    nq = queries.shape[0]
    ids = jnp.full((nq, k), -1, jnp.int32)
    d = jnp.full((nq, k), jnp.inf, jnp.float32)
    n = x.shape[0]
    for s in range(0, n, block):
        xb = x[s : s + block]
        db = squared_dist(queries, xb)
        bids = jnp.arange(s, s + xb.shape[0], dtype=jnp.int32)
        bids = jnp.broadcast_to(bids, db.shape)
        take = min(k, xb.shape[0])
        neg, idx = jax.lax.top_k(-db, take)
        ids, d = merge_topk(ids, d, jnp.take_along_axis(bids, idx, axis=-1), -neg, k)
    return ids, d


def brute_force_knn(x: jnp.ndarray, k: int, block: int = 2048) -> KnnState:
    """Exact KNN graph (self excluded) — small-n oracle and test reference."""
    n = x.shape[0]
    ids_all = []
    d_all = []
    for s in range(0, n, block):
        q = x[s : s + block]
        ids, d = _block_knn_scan(x, q, k + 1)
        self_ids = jnp.arange(s, s + q.shape[0], dtype=jnp.int32)[:, None]
        d = jnp.where(ids == self_ids, jnp.inf, d)
        order = jnp.argsort(d, axis=-1)[:, :k]
        ids_all.append(jnp.take_along_axis(ids, order, axis=-1))
        d_all.append(jnp.take_along_axis(d, order, axis=-1))
    return KnnState(jnp.concatenate(ids_all), jnp.concatenate(d_all))


def _reverse_candidates(ids: jnp.ndarray, r_max: int) -> jnp.ndarray:
    """Reverse edges: for each edge u→v, offer u to v — the shared
    sort-by-segment + rank scatter (``kernels.util.segment_scatter``)."""
    n, k = ids.shape
    src = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, k)).reshape(-1)
    return segment_scatter(ids.reshape(-1), src, n, r_max)


@functools.partial(jax.jit, static_argnames=("k", "block"))
def _blocked_refine(
    x: jnp.ndarray,
    ids: jnp.ndarray,     # (n, k) current neighbor state, -1 pads
    dist: jnp.ndarray,    # (n, k)
    cand: jnp.ndarray,    # (n, Cc) join candidates, -1 pads
    k: int,
    block: int,
):
    """Score ``cand`` against its rows and merge into the top-k state — one
    jitted ``lax.map`` over ``block``-row tiles (the same blocked-scan shape
    as ``build._prune_all``; no untraced Python block loop)."""
    n = x.shape[0]
    n_pad = pad_to(n, block)
    rows = jnp.arange(n_pad, dtype=jnp.int32)
    u_pad = jnp.where(rows < n, rows, 0)
    ids_p = pad_rows(ids, n_pad, -1)
    dist_p = pad_rows(dist, n_pad, jnp.inf)
    cand_p = pad_rows(cand, n_pad, -1)

    def one_block(args):
        u, i_b, d_b, c_b = args
        xc = x[jnp.clip(c_b, 0, n - 1)]
        xu = x[u]
        db = squared_dist(xu[:, None, :], xc)[:, 0, :]
        db = jnp.where((c_b < 0) | (c_b == u[:, None]), jnp.inf, db)
        return merge_topk(i_b, d_b, c_b, db, k)

    mi, md = jax.lax.map(
        one_block,
        (
            u_pad.reshape(-1, block),
            ids_p.reshape(-1, block, ids.shape[1]),
            dist_p.reshape(-1, block, dist.shape[1]),
            cand_p.reshape(-1, block, cand.shape[1]),
        ),
    )
    return mi.reshape(n_pad, k)[:n], md.reshape(n_pad, k)[:n]


def nn_descent(
    key: jax.Array,
    x: jnp.ndarray,
    k: int,
    *,
    iters: int = 6,
    sample: int = 8,
    block: int = 4096,
) -> KnnState:
    """Fixed-width NN-descent: local join over forward, reverse and random
    candidates, merged with blocked matmul distances (``lax.map`` tiles)."""
    n, _ = x.shape
    key, k0 = jax.random.split(key)
    init_ids = jax.random.randint(k0, (n, k), 0, n, dtype=jnp.int32)

    # Initial state: sort + dedup the random seeds (merge into an empty beam).
    empty = jnp.full((n, k), -1, jnp.int32)
    ids, dist = _blocked_refine(
        x, empty, jnp.full((n, k), jnp.inf, jnp.float32), init_ids, k, block
    )

    for it in range(iters):
        key, k1 = jax.random.split(key)
        fwd = ids[:, :sample]                                   # (n, S)
        non = ids[jnp.clip(fwd, 0, n - 1), :sample].reshape(n, sample * sample)
        non = jnp.where(fwd[:, :1] < 0, -1, non)
        rev = _reverse_candidates(ids, sample)
        rnd = jax.random.randint(k1, (n, 4), 0, n, dtype=jnp.int32)
        cand = jnp.concatenate([non, rev, rnd], axis=1)
        ids, dist = _blocked_refine(x, ids, dist, cand, k, block)
    return KnnState(ids, dist)


def attribute_width(ef_attribute: int) -> int:
    """Total attribute-candidate columns: 2 sides × ``ef_attribute/8`` per
    side × 4 sort keys (Alg. 1 lines 3-10).  Owned here so consumers (e.g.
    ``bench_build``'s sweep-shape profile) cannot drift from the builder."""
    return 8 * max(ef_attribute // 8, 1)


def candidate_pool_width(ef_spatial: int, ef_attribute: int) -> int:
    """Iteration-0 candidate-pool width of :func:`generate_candidates`."""
    return ef_spatial + attribute_width(ef_attribute)


def attribute_candidates(intervals: jnp.ndarray, ef_attribute: int) -> jnp.ndarray:
    """Alg. 1 lines 3-10: neighbors in the four interval-derived sort orders."""
    n = intervals.shape[0]
    w = attribute_width(ef_attribute) // 8    # per-side width per sort key
    l = intervals[:, 0]
    r = intervals[:, 1]
    keys = [l, r, (l + r) * 0.5, r - l]
    outs = []
    offsets = jnp.concatenate(
        [jnp.arange(-w, 0, dtype=jnp.int32), jnp.arange(1, w + 1, dtype=jnp.int32)]
    )
    for kv in keys:
        order = jnp.argsort(kv, stable=True).astype(jnp.int32)       # rank -> id
        inv = jnp.zeros((n,), jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
        pos = inv[:, None] + offsets[None, :]                         # (n, 2w)
        ok = (pos >= 0) & (pos < n)
        nb = order[jnp.clip(pos, 0, n - 1)]
        outs.append(jnp.where(ok, nb, -1))
    return jnp.concatenate(outs, axis=1)                              # (n, 8w)


def generate_candidates(
    key: jax.Array,
    x: jnp.ndarray,
    intervals: jnp.ndarray,
    *,
    ef_spatial: int,
    ef_attribute: int,
    nnd_iters: int = 6,
    exact_spatial: bool = False,
) -> jnp.ndarray:
    """Paper Algorithm 1: spatial ∪ attribute candidates, dedup'd, self-free.

    ``exact_spatial=True`` swaps NN-descent for the exact KNN oracle (small n).
    """
    if exact_spatial:
        spa = brute_force_knn(x, ef_spatial).ids
    else:
        spa = nn_descent(key, x, ef_spatial, iters=nnd_iters).ids
    attr = attribute_candidates(intervals, ef_attribute)
    cand = jnp.concatenate([spa, attr], axis=1)
    self_ids = jnp.arange(x.shape[0], dtype=jnp.int32)[:, None]
    cand = jnp.where(cand == self_ids, -1, cand)
    return cand
