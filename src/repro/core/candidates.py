"""Initial candidate generation for UG (paper Alg. 1).

Two complementary sources, exactly as the paper prescribes:

* **spatial** candidates from NN-descent with budget ``ef_spatial`` — the
  navigational backbone;
* **attribute** candidates from the four interval-derived sort keys
  ``{l, r, mid, len}``, taking ``ef_attribute / 8`` adjacent nodes per side
  per key — likely IF/IS witnesses under interval constraints.

The NN-descent here is a TPU-style reformulation: fixed-width neighbor
tensors, the local join expressed as blocked gathers + matmul distances, and
reverse edges recovered with a sort/segment-rank scatter (no dynamic lists).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.prune import squared_dist


class KnnState(NamedTuple):
    ids: jnp.ndarray    # (n, K) int32 neighbor ids, ascending distance, -1 pad
    dist: jnp.ndarray   # (n, K) f32 squared distances (+inf pad)


def merge_topk(ids_a, d_a, ids_b, d_b, k: int):
    """Merge two candidate lists per row, dedup ids, keep the k closest."""
    ids = jnp.concatenate([ids_a, ids_b], axis=-1)
    d = jnp.concatenate([d_a, d_b], axis=-1)
    d = jnp.where(ids < 0, jnp.inf, d)
    # Dedup: sort by id, mask repeats, undo permutation.
    io = jnp.argsort(ids, axis=-1)
    si = jnp.take_along_axis(ids, io, axis=-1)
    dup_sorted = jnp.concatenate(
        [jnp.zeros_like(si[..., :1], bool), (si[..., 1:] == si[..., :-1]) & (si[..., 1:] >= 0)],
        axis=-1,
    )
    dup = jnp.zeros_like(dup_sorted)
    dup = jnp.put_along_axis(dup, io, dup_sorted, axis=-1, inplace=False)
    d = jnp.where(dup, jnp.inf, d)
    order = jnp.argsort(d, axis=-1)[..., :k]
    out_ids = jnp.take_along_axis(ids, order, axis=-1)
    out_d = jnp.take_along_axis(d, order, axis=-1)
    out_ids = jnp.where(jnp.isfinite(out_d), out_ids, -1)
    return out_ids, out_d


def _block_knn_scan(x: jnp.ndarray, queries: jnp.ndarray, k: int, block: int = 4096):
    """Exact top-k of ``queries`` against corpus ``x`` by streaming blocks."""
    nq = queries.shape[0]
    ids = jnp.full((nq, k), -1, jnp.int32)
    d = jnp.full((nq, k), jnp.inf, jnp.float32)
    n = x.shape[0]
    for s in range(0, n, block):
        xb = x[s : s + block]
        db = squared_dist(queries, xb)
        bids = jnp.arange(s, s + xb.shape[0], dtype=jnp.int32)
        bids = jnp.broadcast_to(bids, db.shape)
        take = min(k, xb.shape[0])
        neg, idx = jax.lax.top_k(-db, take)
        ids, d = merge_topk(ids, d, jnp.take_along_axis(bids, idx, axis=-1), -neg, k)
    return ids, d


def brute_force_knn(x: jnp.ndarray, k: int, block: int = 2048) -> KnnState:
    """Exact KNN graph (self excluded) — small-n oracle and test reference."""
    n = x.shape[0]
    ids_all = []
    d_all = []
    for s in range(0, n, block):
        q = x[s : s + block]
        ids, d = _block_knn_scan(x, q, k + 1)
        self_ids = jnp.arange(s, s + q.shape[0], dtype=jnp.int32)[:, None]
        d = jnp.where(ids == self_ids, jnp.inf, d)
        order = jnp.argsort(d, axis=-1)[:, :k]
        ids_all.append(jnp.take_along_axis(ids, order, axis=-1))
        d_all.append(jnp.take_along_axis(d, order, axis=-1))
    return KnnState(jnp.concatenate(ids_all), jnp.concatenate(d_all))


def _reverse_candidates(ids: jnp.ndarray, r_max: int) -> jnp.ndarray:
    """Reverse edges via sort + segment rank: for each edge u→v, offer u to v."""
    n, k = ids.shape
    src = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, k)).reshape(-1)
    dst = ids.reshape(-1)
    valid = dst >= 0
    seg = jnp.where(valid, dst, n)
    order = jnp.argsort(seg, stable=True)
    seg_s = seg[order]
    src_s = src[order]
    first = jnp.searchsorted(seg_s, seg_s, side="left")
    rank = jnp.arange(seg_s.shape[0]) - first
    ok = (seg_s < n) & (rank < r_max)
    out = jnp.full((n + 1, r_max), -1, jnp.int32)
    out = out.at[jnp.where(ok, seg_s, n), jnp.where(ok, rank, 0)].set(
        jnp.where(ok, src_s, -1), mode="drop"
    )
    return out[:n]


def nn_descent(
    key: jax.Array,
    x: jnp.ndarray,
    k: int,
    *,
    iters: int = 6,
    sample: int = 8,
    block: int = 4096,
) -> KnnState:
    """Fixed-width NN-descent: local join over forward, reverse and random
    candidates, merged with blocked matmul distances."""
    n, _ = x.shape
    key, k0 = jax.random.split(key)
    init_ids = jax.random.randint(k0, (n, k), 0, n, dtype=jnp.int32)

    def dists_to(u_ids, cand):
        xc = x[jnp.clip(cand, 0, n - 1)]
        xu = x[u_ids]
        d = squared_dist(xu[:, None, :], xc)[:, 0, :]
        d = jnp.where((cand < 0) | (cand == u_ids[:, None]), jnp.inf, d)
        return d

    state = None
    for s in range(0, n, block):
        u = jnp.arange(s, min(s + block, n), dtype=jnp.int32)
        d = dists_to(u, init_ids[s : s + block])
        ids_b, d_b = merge_topk(
            init_ids[s : s + block], d, jnp.full_like(init_ids[s : s + block], -1), d, k
        )
        state = (
            (ids_b, d_b)
            if state is None
            else (jnp.concatenate([state[0], ids_b]), jnp.concatenate([state[1], d_b]))
        )
    ids, dist = state

    for it in range(iters):
        key, k1 = jax.random.split(key)
        fwd = ids[:, :sample]                                   # (n, S)
        non = ids[jnp.clip(fwd, 0, n - 1), :sample].reshape(n, sample * sample)
        non = jnp.where(fwd[:, :1] < 0, -1, non)
        rev = _reverse_candidates(ids, sample)
        rnd = jax.random.randint(k1, (n, 4), 0, n, dtype=jnp.int32)
        cand = jnp.concatenate([non, rev, rnd], axis=1)

        new_ids = []
        new_d = []
        for s in range(0, n, block):
            u = jnp.arange(s, min(s + block, n), dtype=jnp.int32)
            cb = cand[s : s + block]
            db = dists_to(u, cb)
            mi, md = merge_topk(ids[s : s + block], dist[s : s + block], cb, db, k)
            new_ids.append(mi)
            new_d.append(md)
        ids = jnp.concatenate(new_ids)
        dist = jnp.concatenate(new_d)
    return KnnState(ids, dist)


def attribute_candidates(intervals: jnp.ndarray, ef_attribute: int) -> jnp.ndarray:
    """Alg. 1 lines 3-10: neighbors in the four interval-derived sort orders."""
    n = intervals.shape[0]
    w = max(ef_attribute // 8, 1)
    l = intervals[:, 0]
    r = intervals[:, 1]
    keys = [l, r, (l + r) * 0.5, r - l]
    outs = []
    offsets = jnp.concatenate(
        [jnp.arange(-w, 0, dtype=jnp.int32), jnp.arange(1, w + 1, dtype=jnp.int32)]
    )
    for kv in keys:
        order = jnp.argsort(kv, stable=True).astype(jnp.int32)       # rank -> id
        inv = jnp.zeros((n,), jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
        pos = inv[:, None] + offsets[None, :]                         # (n, 2w)
        ok = (pos >= 0) & (pos < n)
        nb = order[jnp.clip(pos, 0, n - 1)]
        outs.append(jnp.where(ok, nb, -1))
    return jnp.concatenate(outs, axis=1)                              # (n, 8w)


def generate_candidates(
    key: jax.Array,
    x: jnp.ndarray,
    intervals: jnp.ndarray,
    *,
    ef_spatial: int,
    ef_attribute: int,
    nnd_iters: int = 6,
    exact_spatial: bool = False,
) -> jnp.ndarray:
    """Paper Algorithm 1: spatial ∪ attribute candidates, dedup'd, self-free.

    ``exact_spatial=True`` swaps NN-descent for the exact KNN oracle (small n).
    """
    if exact_spatial:
        spa = brute_force_knn(x, ef_spatial).ids
    else:
        spa = nn_descent(key, x, ef_spatial, iters=nnd_iters).ids
    attr = attribute_candidates(intervals, ef_attribute)
    cand = jnp.concatenate([spa, attr], axis=1)
    self_ids = jnp.arange(x.shape[0], dtype=jnp.int32)[:, None]
    cand = jnp.where(cand == self_ids, -1, cand)
    return cand
