"""Exact URNG / RNG reference constructions (paper Def. 3.1, Thm 3.8).

These are the O(n³) oracles used by tests and by the benchmark ground truth.
They evaluate the URNG definition *exactly*: per node, candidates are all
other nodes in ascending-distance order with unbounded degree budgets —
Thm 4.1 shows this coincides with ``UnifiedPrune`` at ``M = ∞`` over the full
candidate graph, so we reuse :func:`repro.core.prune.unified_prune`.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import intervals as iv
from repro.core.prune import unified_prune


class DenseGraph(NamedTuple):
    """Dense directed graph: per-node neighbor ids + semantic bitmask."""

    nbrs: jnp.ndarray    # (n, M) int32, -1 padded, ascending distance
    status: jnp.ndarray  # (n, M) uint8 semantic bitmask

    @property
    def n(self) -> int:
        return self.nbrs.shape[0]

    @property
    def max_degree(self) -> int:
        return self.nbrs.shape[1]

    def degree(self, flag: int) -> jnp.ndarray:
        return jnp.sum(((self.status & flag) > 0) & (self.nbrs >= 0), axis=1)

    def projection(self, sem: iv.Semantics) -> "DenseGraph":
        """Semantic projection G^σ (Thm 3.3): keep only σ-active edges."""
        active = ((self.status & sem.flag) > 0) & (self.nbrs >= 0)
        return DenseGraph(jnp.where(active, self.nbrs, -1), jnp.where(active, self.status, 0))

    def induced(self, node_mask: jnp.ndarray) -> "DenseGraph":
        """Induced subgraph on ``node_mask`` (both endpoints valid)."""
        nbr_ok = (self.nbrs >= 0) & node_mask[jnp.clip(self.nbrs, 0, self.n - 1)]
        nbr_ok = nbr_ok & node_mask[:, None]
        return DenseGraph(
            jnp.where(nbr_ok, self.nbrs, -1), jnp.where(nbr_ok, self.status, 0)
        )


def build_exact(
    x: jnp.ndarray,
    intervals: jnp.ndarray,
    *,
    unified: bool = True,
    alpha: float = 1.0,
    node_mask: jnp.ndarray | None = None,
    block: int = 128,
    backend: str | None = None,
) -> DenseGraph:
    """Exact URNG (``unified=True``) or classical RNG (``unified=False``).

    ``node_mask`` restricts construction to a subset of nodes — used by the
    structural-heredity tests (Thm 3.5/4.1): building on the masked set must
    equal inducing the full graph onto it.  ``backend`` selects the pruning
    sweep implementation (bit-identical across all three, so the oracle is
    backend-independent by construction — asserted in test_exact_urng.py).
    """
    n = x.shape[0]
    ids = np.arange(n, dtype=np.int32)
    if node_mask is not None:
        mask_np = np.asarray(node_mask)
    else:
        mask_np = np.ones((n,), bool)

    # Full candidate row: every valid node (self removed inside unified_prune).
    valid_ids = ids[mask_np]
    cand_row = np.full((n,), -1, np.int32)
    cand_row[: valid_ids.shape[0]] = valid_ids

    nbrs_out = np.full((n, n), -1, np.int32)
    stat_out = np.zeros((n, n), np.uint8)
    u_all = valid_ids
    for s in range(0, u_all.shape[0], block):
        u_blk = jnp.asarray(u_all[s : s + block])
        cand = jnp.asarray(np.broadcast_to(cand_row, (u_blk.shape[0], n)).copy())
        res = unified_prune(
            u_blk, cand, x, intervals, m_if=n, m_is=n, alpha=alpha,
            unified=unified, backend=backend,
        )
        nbrs_out[np.asarray(u_blk)] = np.asarray(res.order)
        stat_out[np.asarray(u_blk)] = np.asarray(res.status)

    # Fully pruned edges carry no semantics: drop them from the adjacency.
    dead = stat_out == 0
    nbrs_out[dead] = -1

    # Compact the column dimension to the max live degree.
    live = nbrs_out >= 0
    max_deg = max(int(live.sum(axis=1).max()), 1)
    comp_n = np.full((n, max_deg), -1, np.int32)
    comp_s = np.zeros((n, max_deg), np.uint8)
    for u in range(n):
        sel = live[u]
        k = int(sel.sum())
        comp_n[u, :k] = nbrs_out[u, sel]
        comp_s[u, :k] = stat_out[u, sel]
    return DenseGraph(jnp.asarray(comp_n), jnp.asarray(comp_s))


def greedy_monotonic_path(
    graph: DenseGraph,
    x: jnp.ndarray,
    sem: iv.Semantics,
    src: int,
    dst: int,
    max_steps: int | None = None,
) -> list[int]:
    """Greedy walk toward ``dst`` along σ-active edges, moving only to
    strictly-closer neighbors (Def. 3.2).  Returns the visited path; reaching
    ``dst`` certifies a monotonic path exists (Thm 3.3 / Cor. 3.4 check)."""
    xn = np.asarray(x, np.float64)
    nbrs = np.asarray(graph.nbrs)
    stat = np.asarray(graph.status)
    tgt = xn[dst]
    cur = src
    path = [cur]
    limit = max_steps or graph.n + 1
    for _ in range(limit):
        if cur == dst:
            return path
        row = nbrs[cur]
        ok = (row >= 0) & ((stat[cur] & sem.flag) > 0)
        if not ok.any():
            return path
        cand = row[ok]
        d = ((xn[cand] - tgt) ** 2).sum(axis=1)
        j = int(np.argmin(d))
        cur_d = ((xn[cur] - tgt) ** 2).sum()
        if d[j] >= cur_d:  # no strictly-closer neighbor: stuck
            return path
        cur = int(cand[j])
        path.append(cur)
    return path
