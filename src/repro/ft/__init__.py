"""Fault tolerance: straggler detection, elastic rescale, resume."""
from repro.ft.elastic import RescalePlan, plan_rescale, resume
from repro.ft.straggler import FleetMonitor, StepTimer, StragglerConfig
