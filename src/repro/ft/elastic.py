"""Elastic rescaling: re-plan the mesh when devices join/leave, and restore
the latest checkpoint re-sharded onto the new mesh.

The checkpoint format stores full logical arrays (ckpt/store.py), so the
restore path is mesh-agnostic — this module only decides the new mesh shape
and drives the re-sharded restore + deterministic data-cursor resume.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.ckpt import store


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    mesh_shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    dropped_pods: int = 0


def plan_rescale(
    n_devices: int,
    *,
    model_parallel: int,
    pods: int = 1,
    axis_names: tuple[str, ...] = ("pod", "data", "model"),
) -> RescalePlan:
    """Choose the largest (pod, data, model) mesh that fits ``n_devices``.

    Model parallelism is preserved (changing TP degree would invalidate the
    parameter layout assumptions of attention-head sharding); pods shrink
    first, then the data axis — matching how real incidents lose capacity.
    """
    if n_devices % model_parallel:
        raise ValueError(
            f"{n_devices} devices not divisible by model_parallel={model_parallel}"
        )
    replicas = n_devices // model_parallel
    use_pods = pods
    while use_pods > 1 and replicas % use_pods:
        use_pods -= 1
    data = replicas // use_pods
    if use_pods > 1:
        return RescalePlan((use_pods, data, model_parallel), axis_names, pods - use_pods)
    return RescalePlan((data, model_parallel), axis_names[1:], pods - 1 if pods > 1 else 0)


def plan_serve_rescale(
    n_devices: int,
    shard_parallel: int,
    *,
    axis_names: tuple[str, ...] = ("replica", "shard"),
) -> RescalePlan:
    """Replica-count planning for a row-sharded serving store (DESIGN.md §13).

    The shard axis plays the role model parallelism plays in training: the
    index is physically partitioned ``shard_parallel`` ways and re-sharding
    it means rebuilding per-shard graphs, so the shard degree is preserved
    and the *replica* (query data-parallel) axis absorbs capacity changes —
    each replica group holds one full copy of the sharded store and serves
    an independent slice of the query traffic.  Devices that do not fill a
    whole replica group are dropped (reported via ``dropped_pods``), exactly
    how a real incident sheds capacity.
    """
    if shard_parallel <= 0 or n_devices <= 0:
        raise ValueError(
            f"need positive device/shard counts, got n_devices={n_devices} "
            f"shard_parallel={shard_parallel}")
    replicas = n_devices // shard_parallel
    if replicas == 0:
        raise ValueError(
            f"{n_devices} devices cannot hold one {shard_parallel}-shard "
            f"replica of the store")
    dropped = n_devices - replicas * shard_parallel
    return RescalePlan((replicas, shard_parallel), axis_names, dropped)


def resume(
    ckpt_dir,
    model,
    opt_template,
    mesh,
    *,
    step: int | None = None,
):
    """Restore latest checkpoint re-sharded onto ``mesh``.

    Returns (params, opt_state, meta) with leaves placed under the new mesh's
    NamedShardings; ``meta["data_cursor"]`` is the deterministic resume point
    for the synthetic pipeline (data is a pure function of (seed, step)).
    """
    pshard = model.shardings(mesh)
    oshard = None
    if opt_template is not None:
        from repro.train import optim

        oshard = optim.AdamWState(None, pshard, pshard)
    return store.restore(
        ckpt_dir,
        step,
        params_template=model.shapes(),
        opt_template=opt_template,
        param_shardings=pshard,
        opt_shardings=oshard,
    )
