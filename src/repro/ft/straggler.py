"""Straggler detection & mitigation hooks (host-side, DESIGN.md §4).

On a real pod every worker reports per-step wall time; a straggler is a
worker whose recent mean exceeds the fleet median by ``z_thresh`` robust
z-scores.  Mitigations (returned as recommendations; the launcher acts):

* ``"recompile_spare"`` — swap in a hot spare and re-shard (elastic path),
* ``"skip_collective_timeout"`` — raise collective timeout for transient
  network jitter,
* ``"checkpoint_now"`` — preemptive checkpoint when degradation is trending.

This module is deliberately pure-python (no jax) so it can run in the
launcher process next to the training loop.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque


@dataclasses.dataclass
class StragglerConfig:
    window: int = 32          # ring buffer of recent step times
    z_thresh: float = 4.0     # robust z-score to flag
    trend_thresh: float = 1.5 # sustained slowdown factor → checkpoint advice


class StepTimer:
    """Per-worker step-time ring buffer with robust outlier detection."""

    def __init__(self, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.times: Deque[float] = deque(maxlen=cfg.window)
        self.baseline: float | None = None

    def record(self, seconds: float) -> None:
        self.times.append(seconds)
        if self.baseline is None and len(self.times) >= 8:
            self.baseline = _median(list(self.times))

    def is_straggling(self) -> bool:
        if len(self.times) < 8 or self.baseline is None:
            return False
        recent = list(self.times)[-8:]
        med = _median(recent)
        mad = _median([abs(t - med) for t in recent]) + 1e-9
        z = (med - self.baseline) / (1.4826 * mad)
        return z > self.cfg.z_thresh

    def recommendation(self) -> str | None:
        if not self.times or self.baseline is None:
            return None
        recent_mean = sum(self.times) / len(self.times)
        if recent_mean > self.cfg.trend_thresh * self.baseline:
            return "checkpoint_now"
        if self.is_straggling():
            return "recompile_spare"
        return None


class FleetMonitor:
    """Aggregates per-worker timers (single-process stand-in for the real
    cross-host heartbeat service)."""

    def __init__(self, n_workers: int, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.timers = [StepTimer(cfg) for _ in range(n_workers)]

    def record(self, worker: int, seconds: float) -> None:
        self.timers[worker].record(seconds)

    def stragglers(self) -> list[int]:
        meds = [
            _median(list(t.times)) if t.times else math.inf for t in self.timers
        ]
        fleet_med = _median([m for m in meds if math.isfinite(m)] or [0.0])
        mad = _median([abs(m - fleet_med) for m in meds if math.isfinite(m)] or [0.0]) + 1e-9
        out = []
        for i, m in enumerate(meds):
            if math.isfinite(m) and (m - fleet_med) / (1.4826 * mad) > self.cfg.z_thresh:
                out.append(i)
        return out


def _median(xs: list[float]) -> float:
    s = sorted(xs)
    n = len(s)
    if n == 0:
        return 0.0
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])
