"""Straggler detection & mitigation hooks (host-side, DESIGN.md §4/§13).

On a real pod every worker reports per-step wall time; a straggler is a
worker whose recent mean exceeds the fleet median by ``z_thresh`` robust
z-scores.  Mitigations (returned as recommendations; the launcher acts):

* ``"recompile_spare"`` — swap in a hot spare and re-shard (elastic path),
* ``"skip_collective_timeout"`` — raise collective timeout for transient
  network jitter,
* ``"checkpoint_now"`` — preemptive checkpoint when degradation is trending.

Baseline discipline: the first ``warmup`` records of every timer are
discarded entirely — they are jit compile time, not steady-state step time,
and folding them into the baseline inflates it so far that real stragglers
are never flagged (and the trend check can misfire on the way *down* from
the compile spike).  Once ``baseline_min`` clean samples exist the baseline
seeds from their median and then tracks the recent median with a slow EMA
(``baseline_alpha``), so benign long-term drift (corpus growth, thermal
throttling recovery) is absorbed while a fast sustained degradation still
trips the ``trend_thresh`` check.

This module is deliberately pure-python (no jax) so it can run in the
launcher/serving process next to the hot loop; serve/runtime.py feeds it
per-shard search timings (DESIGN.md §13).
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque


@dataclasses.dataclass
class StragglerConfig:
    window: int = 32           # ring buffer of recent step times
    z_thresh: float = 4.0      # robust z-score to flag
    trend_thresh: float = 1.5  # sustained slowdown factor → checkpoint advice
    warmup: int = 4            # leading records to discard (jit compile time)
    baseline_min: int = 8      # clean samples before a baseline exists
    baseline_alpha: float = 0.01  # EMA rate of the slowly-updating baseline
    recent: int = 8            # trailing samples the trend/straggle checks use
    min_ratio: float = 1.25    # z-flag also needs this much absolute slowdown


class StepTimer:
    """Per-worker step-time ring buffer with robust outlier detection."""

    def __init__(self, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.times: Deque[float] = deque(maxlen=cfg.window)
        self.baseline: float | None = None
        self._seen = 0  # total records, including discarded warmup

    def record(self, seconds: float) -> None:
        self._seen += 1
        if self._seen <= self.cfg.warmup:
            return  # compile/warmup spike: never enters the window
        self.times.append(seconds)
        if self.baseline is None:
            if len(self.times) >= self.cfg.baseline_min:
                self.baseline = _median(list(self.times))
        else:
            med = _median(self._recent())
            self.baseline += self.cfg.baseline_alpha * (med - self.baseline)

    def _recent(self) -> list[float]:
        r = min(self.cfg.recent, len(self.times))
        return list(self.times)[-r:] if r else []

    def is_straggling(self) -> bool:
        if self.baseline is None or len(self.times) < self.cfg.baseline_min:
            return False
        recent = self._recent()
        med = _median(recent)
        mad = _median([abs(t - med) for t in recent]) + 1e-9
        z = (med - self.baseline) / (1.4826 * mad)
        # The MAD denominator of a steady recent window is ~0, which makes
        # the z-score hypersensitive to any baseline lag (smooth drift would
        # false-alarm); require a material absolute slowdown as well.
        return z > self.cfg.z_thresh and med > self.cfg.min_ratio * self.baseline

    def recommendation(self) -> str | None:
        if not self.times or self.baseline is None:
            return None
        recent_mean = sum(self._recent()) / len(self._recent())
        if recent_mean > self.cfg.trend_thresh * self.baseline:
            return "checkpoint_now"
        if self.is_straggling():
            return "recompile_spare"
        return None


class FleetMonitor:
    """Aggregates per-worker timers (single-process stand-in for the real
    cross-host heartbeat service).  serve/runtime.py points one worker slot
    at every shard of a :class:`~repro.core.sharded.ShardedIndex` and feeds
    per-shard search-step timings through :meth:`record`."""

    def __init__(self, n_workers: int, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.timers = [StepTimer(cfg) for _ in range(n_workers)]

    def record(self, worker: int, seconds: float) -> None:
        self.timers[worker].record(seconds)

    def stragglers(self) -> list[int]:
        """Workers whose recent median is a fleet-level robust outlier."""
        meds = [
            _median(t._recent()) if t.times else math.inf for t in self.timers
        ]
        fleet_med = _median([m for m in meds if math.isfinite(m)] or [0.0])
        mad = _median([abs(m - fleet_med) for m in meds if math.isfinite(m)] or [0.0]) + 1e-9
        out = []
        for i, m in enumerate(meds):
            if (
                math.isfinite(m)
                and (m - fleet_med) / (1.4826 * mad) > self.cfg.z_thresh
                and m > self.cfg.min_ratio * fleet_med
            ):
                out.append(i)
        return out

    def recommendations(self) -> dict[int, str]:
        """Per-worker mitigation advice (workers with none are omitted)."""
        out = {}
        for i, t in enumerate(self.timers):
            rec = t.recommendation()
            if rec is not None:
                out[i] = rec
        return out


def _median(xs: list[float]) -> float:
    s = sorted(xs)
    n = len(s)
    if n == 0:
        return 0.0
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])
