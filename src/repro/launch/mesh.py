"""Production mesh builders (DESIGN.md §4).

Functions, not module-level constants — importing this module never touches
jax device state; the dry-run sets XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import jax


def _mk(shape, axes):
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)  # older jax: no explicit axis types


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape, axes):
    return _mk(tuple(shape), tuple(axes))


def make_host_mesh(model_parallel: int = 1):
    """Whatever this host offers (tests/CPU benches): (n/mp, mp)."""
    n = len(jax.devices())
    mp = model_parallel
    while n % mp:
        mp -= 1
    return _mk((n // mp, mp), ("data", "model"))
