"""End-to-end training driver (deliverable b's train path).

Runs any ``--arch`` at full or reduced scale on whatever devices exist, with
checkpointing, deterministic restart, straggler monitoring and (optionally)
a mid-run elastic rescale drill.  On this CPU container it trains the
reduced configs; on a pod the same file drives the production mesh.

Example::

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --reduced \
        --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp

from repro.ckpt import AsyncCheckpointer, latest_step, restore
from repro.configs.registry import get_arch
from repro.data import LMDataConfig, lm_batch
from repro.ft import StepTimer
from repro.models.api import get_model
from repro.train import AdamWConfig, make_train_step, optim


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", default=None, help="e.g. 4x2 => (data=4, model=2)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    cfg = spec.reduced if args.reduced else spec.config
    model = get_model(cfg)

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_mesh

        dims = tuple(int(x) for x in args.mesh.split("x"))
        names = ("data", "model")[: len(dims)]
        mesh = make_mesh(dims, names)

    ocfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 2),
                       total_steps=args.steps)
    dcfg = LMDataConfig(vocab=cfg.vocab, batch=args.batch, seq=args.seq)

    start_step = 0
    params = opt_state = None
    if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        tmpl_p = model.shapes()
        tmpl_o = jax.eval_shape(lambda p: optim.init(ocfg, p), tmpl_p)
        pshard = model.shardings(mesh) if mesh else None
        params, opt_state, meta = restore(
            args.ckpt_dir, params_template=tmpl_p, opt_template=tmpl_o,
            param_shardings=pshard, opt_shardings=None,
        )
        start_step = meta["data_cursor"]
        print(f"[train] resumed at step {start_step} from {args.ckpt_dir}")
    if params is None:
        params = model.init(jax.random.key(0))
        opt_state = optim.init(ocfg, params)

    step_fn = make_train_step(model, ocfg, mesh, microbatches=args.microbatches,
                              donate=False)
    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    timer = StepTimer()

    frames_kw = {}
    if cfg.family == "encdec":
        frames_kw = dict(frames_dim=cfg.d_model, frames_len=max(args.seq // 2, 4))

    for step in range(start_step, args.steps):
        batch = lm_batch(dcfg, step, **frames_kw)
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        timer.record(dt)
        if step % args.log_every == 0 or step == args.steps - 1:
            rec = timer.recommendation()
            print(f"[train] step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms"
                  + (f"  [ft: {rec}]" if rec else ""))
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, params, opt_state, data_cursor=step + 1)
    if ckpt:
        ckpt.save(args.steps, params, opt_state, data_cursor=args.steps)
        ckpt.wait()
        print(f"[train] final checkpoint at {ckpt.last_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
