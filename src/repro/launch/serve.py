"""End-to-end interval-aware retrieval serving (the paper's deployment).

Pipeline: LM tower embeds a synthetic document corpus → UG unified index is
built over (embedding, validity-interval) pairs → batched queries run under
all four semantics (IFANN / ISANN / RFANN / RSANN) against brute-force truth.

Example::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b --reduced \
        --docs 2000 --queries 64
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.core import Semantics, UGConfig, UGIndex, recall
from repro.core import intervals as iv
from repro.models.api import get_model
from repro.serve import ServeEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen1.5-4b")
    # store_true + default=True made --reduced a no-op (the full-size config
    # was unreachable); BooleanOptionalAction restores --no-reduced.
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True, help="use the reduced config (--no-reduced "
                    "serves the full-size architecture)")
    ap.add_argument("--docs", type=int, default=2000)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--doc-len", type=int, default=32)
    ap.add_argument("--ef", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--backend", default=None,
                    choices=["pallas", "xla", "legacy"],
                    help="search pipeline (default: fused; pallas on TPU, xla on CPU)")
    ap.add_argument("--width", type=int, default=4,
                    help="fused multi-expansion frontier width W")
    ap.add_argument("--dtype", default="f32",
                    choices=["f32", "bf16", "int8", "pq"],
                    help="vector scan plane of the served index (int8/pq "
                         "auto-attach the f32 rerank plane; DESIGN.md §12/§14)")
    ap.add_argument("--mixed", action="store_true",
                    help="also serve one interleaved IF/IS/RF/RS stream "
                         "through the runtime-semantics path and compare "
                         "against four per-semantics batches")
    ap.add_argument("--dynamic", action="store_true",
                    help="churn demo: delete 10%% of the corpus and upsert "
                         "replacement docs through the streaming update "
                         "subsystem (DESIGN.md §11), then re-evaluate recall")
    ap.add_argument("--async", dest="async_serve", action="store_true",
                    help="async continuous-batching demo: stream the mixed "
                         "workload through ServeRuntime with per-request "
                         "deadlines and concurrent churn writes, printing "
                         "sustained QPS and p50/p99 (DESIGN.md §13)")
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    cfg = spec.reduced if args.reduced else spec.config
    if cfg.family == "encdec":
        print("[serve] encdec tower: using decoder-only embedding of tokens")
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params)

    # 1) embed the corpus with the LM tower
    key = jax.random.key(1)
    k_doc, k_iv, k_q = jax.random.split(key, 3)
    doc_tokens = jax.random.randint(k_doc, (args.docs, args.doc_len), 0, cfg.vocab)
    t0 = time.perf_counter()
    embs = []
    bs = 256
    for s in range(0, args.docs, bs):
        embs.append(engine.embed(doc_tokens[s : s + bs]))
    x = jnp.concatenate(embs)
    print(f"[serve] embedded {args.docs} docs (d={x.shape[1]}) "
          f"in {time.perf_counter() - t0:.1f}s")

    # 2) validity intervals (uniform interval model §3.2) + unified index
    intervals = iv.sample_uniform_intervals(k_iv, args.docs)
    ucfg = UGConfig(ef_spatial=32, ef_attribute=64, max_edges_if=32,
                    max_edges_is=32, iterations=3, repair_width=16,
                    exact_spatial=args.docs <= 4096)
    idx = UGIndex.build(x, intervals, ucfg, dtype=args.dtype)
    engine.attach_index(idx, backend=args.backend, width=args.width)
    vm = idx.vector_memory_bytes()
    print(f"[serve] UG built in {idx.build_seconds:.1f}s "
          f"({args.dtype} plane, {vm['plane_bytes_per_vector']:.1f} B/vec) "
          f"degree stats {idx.degree_stats()}")

    # 3) queries under all four semantics (one index!)
    q_tokens = jax.random.randint(k_q, (args.queries, args.doc_len), 0, cfg.vocab)
    qv = engine.embed(q_tokens)
    c = jax.random.uniform(jax.random.fold_in(k_q, 1), (args.queries, 1))
    wide = jnp.concatenate(
        [jnp.maximum(c - 0.3, 0.0), jnp.minimum(c + 0.3, 1.0)], axis=1
    )
    point = jnp.concatenate([c, c], axis=1)

    for sem, qint in [
        (Semantics.IF, wide), (Semantics.IS, wide),
        (Semantics.RS, point), (Semantics.RF, wide),
    ]:
        t0 = time.perf_counter()
        # qv was embedded once above; timing stays search-only and comparable
        # across semantics (the embed cost is semantics-independent).
        res = engine.retrieve(None, qint, sem=sem, ef=args.ef, k=args.k, q_v=qv)
        jax.block_until_ready(res.ids)
        dt = time.perf_counter() - t0
        gt = idx.ground_truth(qv, qint, sem=sem, k=args.k)
        r = recall(res, gt)
        qps = args.queries / dt
        print(f"[serve] {sem.value}: recall@{args.k} {r:.3f}  "
              f"QPS {qps:,.0f}  mean hops {float(res.steps.mean()):.1f}")

    # 4) mixed workload: every request carries its own semantics; one
    #    compiled program serves the interleaved stream (DESIGN.md §10)
    if args.mixed:
        cycle = [Semantics.IF, Semantics.IS, Semantics.RS, Semantics.RF]
        sems = [cycle[i % 4] for i in range(args.queries)]
        is_rs = jnp.asarray([s is Semantics.RS for s in sems])
        qmix = jnp.where(is_rs[:, None], point, wide)

        def run_mixed():
            return engine.retrieve_mixed(None, qmix, sems, ef=args.ef,
                                         k=args.k, q_v=qv)

        res = run_mixed()  # warmup/compile
        t0 = time.perf_counter()
        res = run_mixed()
        jax.block_until_ready(res.ids)
        dt_mixed = time.perf_counter() - t0

        subsets = {s: [i for i, ss in enumerate(sems) if ss is s] for s in cycle}

        # keyed by sem value: enum keys are not sortable as a jax pytree
        def run_split():
            return {s.value: engine.retrieve(None, qmix[jnp.asarray(sel)],
                                             sem=s, ef=args.ef, k=args.k,
                                             q_v=qv[jnp.asarray(sel)])
                    for s, sel in subsets.items()}

        outs = run_split()  # warmup/compile
        t0 = time.perf_counter()
        outs = run_split()
        jax.block_until_ready(outs)  # all four batches, not just the last
        dt_split = time.perf_counter() - t0

        recs = []
        for s, sel in subsets.items():
            sel = jnp.asarray(sel)
            gt = idx.ground_truth(qv[sel], qmix[sel], sem=s, k=args.k)
            part = type(res)(res.ids[sel], res.dist[sel], res.steps[sel])
            recs.append(f"{s.value}={recall(part, gt):.3f}")
        # batch-synchronous iteration counts: the hardware-independent QPS
        # signal (CPU wall-clock is B-linear per iteration; DESIGN.md §10)
        it_mixed = int(res.iters)
        it_split = sum(int(outs[s.value].iters) for s in cycle)
        print(f"[serve] mixed 4-semantics stream: QPS {args.queries/dt_mixed:,.0f} "
              f"vs split-by-semantics QPS {args.queries/dt_split:,.0f} "
              f"({dt_split/dt_mixed:.2f}x wall)  sync iters {it_mixed} vs "
              f"{it_split} ({it_split/max(it_mixed, 1):.2f}x)  "
              f"recall@{args.k} {' '.join(recs)}")

    # 5) dynamic churn: the streaming update subsystem (DESIGN.md §11) —
    #    tombstone deletes + iterative repair, then bucketed upserts; the
    #    same index keeps serving all four semantics without a rebuild
    if args.dynamic:
        import numpy as np

        n_churn = max(args.docs // 10, 1)
        rng = np.random.default_rng(5)
        dead = jnp.asarray(
            rng.choice(args.docs, size=n_churn, replace=False).astype(np.int32)
        )
        t0 = time.perf_counter()
        engine.remove(dead)
        jax.block_until_ready(engine.index.graph.nbrs)
        dt_del = time.perf_counter() - t0
        new_tokens = jax.random.randint(
            jax.random.fold_in(k_doc, 9), (n_churn, args.doc_len), 0, cfg.vocab
        )
        new_iv = iv.sample_uniform_intervals(jax.random.fold_in(k_iv, 9), n_churn)
        t0 = time.perf_counter()
        engine.upsert(new_tokens, new_iv)
        jax.block_until_ready(engine.index.graph.nbrs)
        dt_ins = time.perf_counter() - t0
        idx2 = engine.index
        print(f"[serve] dynamic churn: {n_churn} deletes in {dt_del:.1f}s "
              f"({n_churn/dt_del:,.0f}/s), {n_churn} upserts in {dt_ins:.1f}s "
              f"({n_churn/dt_ins:,.0f}/s); {idx2.n} live of "
              f"{idx2.capacity} slots")
        for sem, qint in [(Semantics.IF, wide), (Semantics.IS, wide)]:
            res = engine.retrieve(None, qint, sem=sem, ef=args.ef, k=args.k,
                                  q_v=qv)
            gt = idx2.ground_truth(qv, qint, sem=sem, k=args.k)
            print(f"[serve] {sem.value} after churn: "
                  f"recall@{args.k} {recall(res, gt):.3f}")

    # 6) async serving: the continuous-batching runtime (DESIGN.md §13) —
    #    requests trickle in one at a time with their own semantics + a
    #    deadline, writes churn the corpus mid-stream, and the coalescer
    #    re-packs everything into bucket-shaped micro-batches for the same
    #    compiled programs the batched path uses
    if args.async_serve:
        from repro.serve import RuntimeConfig, ServeRuntime

        cycle = [Semantics.IF, Semantics.IS, Semantics.RS, Semantics.RF]
        sems = [cycle[i % 4] for i in range(args.queries)]
        is_rs = jnp.asarray([s is Semantics.RS for s in sems])
        qmix = jnp.where(is_rs[:, None], point, wide)
        n_churn = max(args.docs // 20, 1)
        new_x = engine.embed(jax.random.randint(
            jax.random.fold_in(k_doc, 11), (n_churn, args.doc_len), 0,
            cfg.vocab))
        new_iv = iv.sample_uniform_intervals(jax.random.fold_in(k_iv, 11),
                                             n_churn)
        # warm the bucket programs so the measured stream is compile-free
        engine.retrieve_mixed(None, qmix[:1], sems[:1], ef=args.ef,
                              k=args.k, q_v=qv[:1])
        with ServeRuntime(engine, RuntimeConfig(max_batch=64)) as rt:
            futs = []
            wfut = None
            for i in range(args.queries):
                # generous deadline: the first mid-stream upsert pays one-off
                # jit compiles that dwarf steady-state service time
                futs.append(rt.submit(
                    qv[i], qmix[i], sems[i], ef=args.ef, k=args.k,
                    deadline=rt.clock() + 600.0))
                if i == args.queries // 2:  # churn mid-stream
                    wfut = rt.submit_upsert(new_x, new_iv)
            replies = [f.result(timeout=120) for f in futs]
            s = rt.stats()
        pre = sum(1 for r in replies if r.index is not engine.index)
        print(f"[serve] async runtime: {s['completed']} served "
              f"({s['rejected']} rejected, {wfut.result()} docs upserted "
              f"mid-stream; {pre} answered pre-write snapshot) "
              f"QPS {s['qps']:,.1f}  p50 {s['p50_ms']:.1f}ms  "
              f"p99 {s['p99_ms']:.1f}ms")
    return 0


if __name__ == "__main__":
    sys.exit(main())
