"""HLO analysis for the roofline: loop-aware collective-byte accounting.

``cost_analysis()`` gives FLOPs and memory bytes but not collective traffic,
so we parse the post-SPMD per-device HLO (``compiled.as_text()``):

1. build a symbol table of every op's result shape (bytes);
2. find every collective op (all-reduce, all-gather, reduce-scatter,
   all-to-all, collective-permute) and sum its *operand* bytes;
3. weight ops inside ``while`` bodies by the loop trip count, recovered from
   the loop condition's comparison constant (scan-over-layers runs its body
   n_layers times — static summing would undercount 94× on qwen3-moe).

The same trip-count machinery cross-checks cost_analysis FLOPs (XLA's
HloCostAnalysis also visits while bodies once on some backends; the
``flops_scale_hint`` lets the roofline reconcile).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# computation header: "%name (args...) -> type {" (args may nest parens)
_COMP_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-$]+)\s*\(.*->.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\)?.*condition=%?([\w.\-]+).*body=%?([\w.\-]+)", re.S
)
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(sig: str) -> int:
    """Total bytes of a (possibly tuple) HLO type signature."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(sig):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Op:
    name: str
    sig: str
    opcode: str
    line: str


def parse_computations(hlo: str) -> dict[str, list[Op]]:
    comps: dict[str, list[Op]] = {}
    current = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        m = _COMP_RE.match(line)
        if m and line.endswith("{"):
            current = m.group(1)
            comps[current] = []
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        d = _DEF_RE.match(line)
        if d:
            name = d.group(1).lstrip("%")
            comps[current].append(Op(name, d.group(2), d.group(3), line))
    return comps


def _symbol_table(comps: dict[str, list[Op]]) -> dict[str, int]:
    table: dict[str, int] = {}
    for ops in comps.values():
        for op in ops:
            table[op.name] = _shape_bytes(op.sig)
    return table


def _trip_count(cond_ops: list[Op]) -> int:
    """Recover the loop bound from the condition computation's constants.

    XLA often hides the compare inside a kLoop fusion; the bound constant is
    still defined (or literal) in the condition computation, so we take the
    largest integer constant found there — induction starts/strides are 0/1.
    """
    consts = []
    for op in cond_ops:
        if op.opcode == "constant":
            m = _CONST_RE.search(op.line)
            if m:
                consts.append(int(m.group(1)))
    return max(consts, default=1)


_OPERAND_RE = re.compile(r"\(([^)]*)\)")


def _operand_bytes(op: Op, table: dict[str, int]) -> int:
    """Sum the operand sizes referenced inside the op's parens."""
    m = _OPERAND_RE.search(op.line.split(op.opcode, 1)[-1])
    if not m:
        return 0
    total = 0
    for tok in m.group(1).split(","):
        tok = tok.strip().lstrip("%")
        tok = tok.split(" ")[-1].lstrip("%")  # "bf16[8,16] %name" form
        if tok in table:
            total += table[tok]
    if total == 0:
        # operand names not resolvable — fall back to result size
        total = _shape_bytes(op.sig)
    return total


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: int
    by_type: dict[str, int]
    by_computation: dict[str, int]
    trip_counts: dict[str, int]

    def fmt(self) -> str:
        rows = [f"  total collective operand bytes/device: {self.total_bytes:,}"]
        for k, v in sorted(self.by_type.items(), key=lambda kv: -kv[1]):
            rows.append(f"    {k:20s} {v:,}")
        return "\n".join(rows)


def collective_bytes(hlo: str) -> CollectiveStats:
    comps = parse_computations(hlo)
    table = _symbol_table(comps)

    # map body computation -> trip count (via the while ops that call it)
    trip: dict[str, int] = defaultdict(lambda: 1)
    for ops in comps.values():
        for op in ops:
            if op.opcode == "while":
                m = _WHILE_RE.search(op.line)
                if m:
                    cond, body = m.group(1), m.group(2)
                    if cond in comps:
                        trip[body] = _trip_count(comps[cond])

    # weight of each computation = product of enclosing loop trips; we
    # approximate nesting by iterating to fixpoint over call edges
    weight: dict[str, int] = {name: 1 for name in comps}
    call_re = re.compile(r"(?:body|to_apply|condition)=%?([\w.\-]+)")
    for _ in range(4):  # enough for realistic nesting depth
        new = dict(weight)
        for name, ops in comps.items():
            for op in ops:
                for callee in call_re.findall(op.line):
                    if callee in comps:
                        t = trip[callee] if op.opcode == "while" and callee != name else 1
                        w = weight[name] * (t if t > 1 else 1)
                        if w > new.get(callee, 1):
                            new[callee] = w
        weight = new

    by_type: dict[str, int] = defaultdict(int)
    by_comp: dict[str, int] = defaultdict(int)
    for name, ops in comps.items():
        for op in ops:
            if any(op.opcode.startswith(c) for c in COLLECTIVE_OPS):
                b = _operand_bytes(op, table) * weight.get(name, 1)
                key = op.opcode
                for c in COLLECTIVE_OPS:
                    if op.opcode.startswith(c):
                        key = c
                        break
                by_type[key] += b
                by_comp[name] += b
    return CollectiveStats(
        sum(by_type.values()), dict(by_type), dict(by_comp),
        {k: v for k, v in trip.items() if v > 1},
    )


def loop_weighted_flops_hint(hlo: str) -> dict[str, int]:
    """Trip counts of all while loops (for reconciling cost_analysis FLOPs)."""
    comps = parse_computations(hlo)
    out = {}
    for ops in comps.values():
        for op in ops:
            if op.opcode == "while":
                m = _WHILE_RE.search(op.line)
                if m and m.group(1) in comps:
                    out[m.group(2)] = _trip_count(comps[m.group(1)])
    return out


# ---------------------------------------------------------------------------
# Loop-weighted analytic FLOPs and HBM bytes
# ---------------------------------------------------------------------------
_CALL_RE = re.compile(r"(body|condition|calls|to_apply)=%?([\w.\-$]+)")
_DIMS_RE = re.compile(r"(lhs|rhs)_contracting_dims=\{([\d,]*)\}")
_NOBYTES_OPS = {
    "get-tuple-element", "parameter", "constant", "bitcast", "tuple",
    "after-all", "partition-id", "replica-id", "iota", "copy-start",
    "copy-done",
}

# Ops that touch HBM on TPU.  The CPU backend leaves many layout/elementwise
# ops unfused that Mosaic/XLA-TPU would fuse into neighbors; counting every
# top-level op's operands+results would double- or triple-count each value.
_HBM_OPS = {
    "dot", "convolution", "fusion", "custom-call", "scatter", "gather",
    "dynamic-slice", "dynamic-update-slice", "reduce", "reduce-window",
    "sort", "select-and-scatter", "all-gather", "all-reduce",
    "reduce-scatter", "all-to-all", "collective-permute", "rng",
    "rng-bit-generator", "cholesky", "triangular-solve", "fft",
}


def _computation_weights(comps: dict[str, list[Op]]):
    """weight[c] = product of enclosing while trip counts (call-graph fixpoint)."""
    trips: dict[str, int] = {}
    edges: list[tuple[str, str, int]] = []  # (caller, callee, multiplier)
    for name, ops in comps.items():
        for op in ops:
            trip = 1
            if op.opcode == "while":
                m = _WHILE_RE.search(op.line)
                if m and m.group(1) in comps:
                    trip = _trip_count(comps[m.group(1)])
                    trips[m.group(2)] = trip
            for kind, callee in _CALL_RE.findall(op.line):
                if callee in comps:
                    mult = trip if (op.opcode == "while" and kind == "body") else 1
                    edges.append((name, callee, mult))
    weight = {name: 0 for name in comps}
    for entry in comps:
        if entry.startswith("main") or ".main" in entry or entry == "entry":
            weight[entry] = 1
    if not any(weight.values()):
        # fall back: first computation named like ENTRY
        first = next(iter(comps))
        weight[first] = 1
    for _ in range(8):
        changed = False
        for caller, callee, mult in edges:
            w = weight.get(caller, 0) * max(mult, 1)
            if w > weight.get(callee, 0):
                weight[callee] = w
                changed = True
        if not changed:
            break
    return weight, trips


def _dot_flops(op: Op, table_shape: dict[str, tuple[str, tuple[int, ...]]]) -> int:
    """2 × |result| × K for a dot op (K from lhs contracting dims)."""
    res = _SHAPE_RE.search(op.sig)
    if not res:
        return 0
    out_elems = 1
    if res.group(2):
        for d in res.group(2).split(","):
            out_elems *= int(d)
    m = _OPERAND_RE.search(op.line.split(op.opcode, 1)[-1])
    lhs_name = None
    if m:
        toks = [t.strip().lstrip("%").split(" ")[-1].lstrip("%")
                for t in m.group(1).split(",")]
        lhs_name = toks[0] if toks else None
    dims = dict(_DIMS_RE.findall(op.line))
    k = 1
    if lhs_name and lhs_name in table_shape and "lhs" in dims and dims["lhs"]:
        _, shape = table_shape[lhs_name]
        for d in dims["lhs"].split(","):
            di = int(d)
            if di < len(shape):
                k *= shape[di]
    return 2 * out_elems * k


def _shape_of(sig: str) -> tuple[str, tuple[int, ...]]:
    m = _SHAPE_RE.search(sig)
    if not m:
        return ("", ())
    dims = tuple(int(d) for d in m.group(2).split(",")) if m.group(2) else ()
    return (m.group(1), dims)


@dataclasses.dataclass
class HloStats:
    flops: float            # loop-weighted dot/conv FLOPs per device
    hbm_bytes: float        # loop-weighted top-level operand+result bytes
    collectives: CollectiveStats


def analyze_hlo(hlo: str) -> HloStats:
    """Loop-weighted FLOPs / HBM bytes / collective bytes for one module."""
    comps = parse_computations(hlo)
    table = _symbol_table(comps)
    shape_table: dict[str, tuple[str, tuple[int, ...]]] = {}
    for ops in comps.values():
        for op in ops:
            shape_table[op.name] = _shape_of(op.sig)
    weight, trips = _computation_weights(comps)

    flops = 0.0
    hbm = 0.0
    by_type: dict[str, int] = defaultdict(int)
    for name, ops in comps.items():
        w = weight.get(name, 0)
        if w <= 0:
            continue
        for op in ops:
            if op.opcode in ("dot", "convolution"):
                flops += w * _dot_flops(op, shape_table)
            if op.opcode in _NOBYTES_OPS:
                continue
            # top-level data movement: operands + result, restricted to ops
            # that touch HBM on TPU (fusion internals and fuse-away layout /
            # elementwise ops excluded — see _HBM_OPS note)
            if (
                op.opcode in _HBM_OPS
                and not name.endswith("_computation")
                and "fused" not in name
            ):
                hbm += w * (_operand_bytes(op, table) + _shape_bytes(op.sig))
            if any(op.opcode.startswith(c) for c in COLLECTIVE_OPS):
                b = _operand_bytes(op, table) * w
                for c in COLLECTIVE_OPS:
                    if op.opcode.startswith(c):
                        by_type[c] += b
                        break
    coll = CollectiveStats(sum(by_type.values()), dict(by_type), {}, trips)
    return HloStats(flops, hbm, coll)
