"""Roofline analysis (deliverable g): three terms per dry-run record.

    compute    = HLO_FLOPs   / (chips × 197e12 FLOP/s bf16)
    memory     = HLO_bytes   / (chips × 819e9  B/s HBM)
    collective = coll_bytes  / (chips × 50e9   B/s ICI per link)

HLO figures from ``cost_analysis()`` are per-device for the SPMD-partitioned
module, so ``chips`` divides only the hardware constants' aggregate — i.e.
terms are simply per-device quantities over per-chip rates.  MODEL_FLOPS is
6·N·D (dense) or 6·N_active·D (MoE) per the harness definition; its ratio to
(HLO_FLOPs × chips) flags remat/redundancy waste.

Reads the JSONL written by ``repro.launch.dryrun`` and emits the §Roofline
table for EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys

PEAK_FLOPS = 197e12     # bf16 per chip (TPU v5e-class)
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link

_CHIPS = {"single": 256, "multi": 512}


def model_flops(arch: str, shape: dict) -> float:
    """6·N(_active)·D per the harness definition (D = tokens processed)."""
    from repro.configs.registry import ARCHS, SHAPES

    if arch not in ARCHS:
        return 0.0
    cfg = ARCHS[arch].config
    sh = SHAPES[shape["shape"]] if isinstance(shape, dict) else SHAPES[shape]
    n_active = cfg.active_param_count()
    if sh.kind == "train":
        tokens = sh.global_batch * sh.seq_len
        return 6.0 * n_active * tokens
    if sh.kind == "prefill":
        tokens = sh.global_batch * sh.seq_len
        return 2.0 * n_active * tokens        # forward only
    # decode: one token per request
    return 2.0 * n_active * sh.global_batch


def analyze(rec: dict) -> dict:
    chips = _CHIPS.get(rec.get("mesh", "single"), 256)
    flops_dev = rec.get("flops", 0.0)
    bytes_dev = rec.get("bytes_accessed", 0.0)
    coll_dev = rec.get("collective_bytes", 0)

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(rec.get("arch", ""), rec)
    hlo_total = flops_dev * chips
    useful = mf / hlo_total if hlo_total else 0.0
    # roofline fraction: useful work over what the dominant term's time buys
    step_time = bound
    achievable = mf / (chips * PEAK_FLOPS)
    frac = achievable / step_time if step_time > 0 else 0.0
    return {
        **{k: rec.get(k) for k in ("arch", "shape", "mesh", "ok", "skipped")},
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": useful,
        "roofline_fraction": frac,
    }


def fmt_table(rows: list[dict]) -> str:
    hdr = (
        f"{'arch':26s} {'shape':12s} {'mesh':6s} {'compute(s)':>11s} "
        f"{'memory(s)':>11s} {'coll(s)':>11s} {'bound':>10s} "
        f"{'useful':>7s} {'roofline':>9s}"
    )
    out = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("skipped"):
            out.append(f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:6s} "
                       f"{'— skipped: sub-quadratic attention required —':>62s}")
            continue
        if not r.get("ok", True):
            out.append(f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:6s} FAILED")
            continue
        out.append(
            f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:6s} "
            f"{r['t_compute_s']:11.4f} {r['t_memory_s']:11.4f} "
            f"{r['t_collective_s']:11.4f} {r['dominant']:>10s} "
            f"{r['useful_ratio']:7.3f} {r['roofline_fraction']:9.3f}"
        )
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("jsonl", help="dryrun JSONL file")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)
    rows = []
    seen = {}
    for line in pathlib.Path(args.jsonl).read_text().splitlines():
        rec = json.loads(line)
        seen[(rec.get("arch"), rec.get("shape"), rec.get("mesh"))] = rec
    for rec in seen.values():
        rows.append(analyze(rec))
    print(fmt_table(rows))
    if args.json_out:
        pathlib.Path(args.json_out).write_text(json.dumps(rows, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
