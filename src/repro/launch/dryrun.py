import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input-shape × mesh) cell with ShapeDtypeStruct stand-ins —
no allocation — and record memory analysis, FLOP/byte costs and the
loop-weighted collective bytes for the roofline (EXPERIMENTS.md §Dry-run).

Usage::

    python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh multi --out results/dryrun.jsonl
    python -m repro.launch.dryrun --index-cell --mesh single   # the paper's
        sharded UG search step as its own dry-run cell

Exit code != 0 on any failed cell: failures here are sharding bugs.
"""
import argparse
import json
import pathlib
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ARCHS, SHAPES, get_arch, input_specs
from repro.launch import shardings as shard_lib
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.models import shard_ctx
from repro.models.api import get_model
from repro.train import optim


def _sds_tree(tree):
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def build_cell(arch_name: str, shape_name: str, mesh, *, moe_a2a: bool = False,
               remat_policy: str | None = None):
    """Returns (fn, example_args, in_shardings, out_shardings)."""
    spec = get_arch(arch_name)
    cfg = spec.config
    if remat_policy is not None:
        import dataclasses
        cfg = dataclasses.replace(cfg, remat=remat_policy != "none")
    shape = SHAPES[shape_name]
    model = get_model(cfg)
    pshard = model.shardings(mesh)
    params_sds = model.shapes()
    rep = NamedSharding(mesh, P())

    if shape.kind == "train":
        ocfg = optim.AdamWConfig(
            state_dtype=jnp.bfloat16 if cfg.moe else jnp.float32
        )
        opt_sds = jax.eval_shape(lambda p: optim.init(ocfg, p), params_sds)
        opt_shard = optim.AdamWState(rep, pshard, pshard)
        batch = input_specs(cfg, shape)
        bshard = shard_lib.batch_shardings(mesh)

        def train_step(params, opt_state, b):
            (loss, _), grads = jax.value_and_grad(
                lambda p: model.loss(p, b), has_aux=True
            )(params)
            new_p, new_o, stats = optim.update(ocfg, opt_state, params, grads)
            return new_p, new_o, loss

        return (
            train_step,
            (params_sds, opt_sds, batch),
            (pshard, opt_shard, bshard),
            (pshard, opt_shard, rep),
            (0, 1),   # donate params + opt state (in-place update)
        )

    if shape.kind == "prefill":
        batch = input_specs(cfg, shape)
        bshard = shard_lib.batch_shardings(mesh)

        def prefill_step(params, b):
            hidden, caches = model.prefill(params, b)
            # serving returns last-position logits (next-token readiness)
            from repro.models import transformer as tr

            logits = tr.unembed(cfg, params, hidden[:, -1:, :])
            return logits, caches

        return (prefill_step, (params_sds, batch), (pshard, bshard), None, ())

    # decode
    B, S = shape.global_batch, shape.seq_len
    inputs = input_specs(cfg, shape)
    state_sds, tok_sds = inputs["state"], inputs["tokens"]
    sshard = shard_lib.decode_state_shardings(cfg, mesh, B, S)
    tshard = shard_lib.token_sharding(mesh, B)

    def serve_step(params, state, tokens):
        new_state, logits = model.decode_step(params, state, tokens)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return new_state, next_tok

    return (
        serve_step,
        (params_sds, state_sds, tok_sds),
        (pshard, sshard, tshard),
        (sshard, tshard),
        (1,),     # donate the decode state (in-place cache update)
    )


def build_index_cell(mesh, *, n_global=1 << 20, dim=768, m_deg=64,
                     ef=64, k=10, nq=1024, hierarchical=True):
    """The paper's own technique as a dry-run cell: sharded UG search step."""
    from repro.core import intervals as iv
    from repro.core.sharded import (
        ShardedIndex, make_sharded_search_fn, store_pspecs,
    )
    from repro.core.store import IndexStore, VectorPlane

    index_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    fn = make_sharded_search_fn(
        mesh, index_axes=index_axes, sem=iv.Semantics.IF, ef=ef, k=k,
        hierarchical=hierarchical,
    )
    row = NamedSharding(mesh, P(index_axes))
    rep = NamedSharding(mesh, P())
    sds = lambda s, d: jax.ShapeDtypeStruct(s, d)
    store_sds = IndexStore(
        plane=VectorPlane("f32", sds((n_global, dim), jnp.float32)),
        rerank=None,
        intervals=sds((n_global, 2), jnp.float32),
        nbrs=sds((n_global, m_deg), jnp.int32),
        status=sds((n_global, m_deg), jnp.uint8),
        entry=None,
    )
    sidx = ShardedIndex(store_sds, sds((n_global,), jnp.int32))
    args = (
        sidx,
        sds((nq, dim), jnp.float32),           # queries
        sds((nq, 2), jnp.float32),             # query intervals
    )
    sidx_shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        ShardedIndex(store_pspecs(store_sds, index_axes), P(index_axes)),
        is_leaf=lambda v: isinstance(v, P),
    )
    shardings = (sidx_shardings, rep, rep)
    return fn, args, shardings, None


def run_cell(arch: str, shape: str, mesh_kind: str, *, index_cell=False,
             moe_a2a=False, verbose=True) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_kind,
        "mesh_shape": dict(mesh.shape), "ok": False,
    }
    try:
        if index_cell:
            fn, args, in_sh, out_sh = build_index_cell(mesh)
            donate = ()
            rec["arch"] = "ug-index-search"
        else:
            spec = get_arch(arch)
            skip = spec.skip_reason(shape)
            if skip:
                rec.update(ok=True, skipped=skip)
                return rec
            fn, args, in_sh, out_sh, donate = build_cell(arch, shape, mesh, moe_a2a=moe_a2a)

        with shard_ctx.use_mesh(mesh):
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # older jax: one dict per device
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        hlo_dir = pathlib.Path("results/hlo")
        hlo_dir.mkdir(parents=True, exist_ok=True)
        import gzip

        tag = f"{rec['arch']}_{shape}_{mesh_kind}".replace("/", "-")
        with gzip.open(hlo_dir / f"{tag}.hlo.gz", "wt") as f:
            f.write(hlo)
        stats = analyze_hlo(hlo)

        rec.update(
            ok=True,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            # loop-weighted analytic numbers (while bodies × trip count);
            # raw cost_analysis kept for cross-checking (visits loops once)
            flops=float(stats.flops),
            bytes_accessed=float(stats.hbm_bytes),
            xla_flops=float(cost.get("flops", 0.0)),
            xla_bytes=float(cost.get("bytes accessed", 0.0)),
            mem=_mem_dict(mem),
            collective_bytes=stats.collectives.total_bytes,
            collective_by_type=stats.collectives.by_type,
            loop_trip_counts={
                k: v for k, v in sorted(stats.collectives.trip_counts.items())[:16]
            },
        )
        if verbose:
            print(f"[dryrun] {rec['arch']} × {shape} × {mesh_kind}: OK "
                  f"(compile {rec['compile_s']}s)")
            print(f"  memory: {rec['mem']}")
            print(f"  flops/device: {rec['flops']:.3e}  "
                  f"bytes/device: {rec['bytes_accessed']:.3e}")
            print(stats.collectives.fmt())
    except Exception as e:  # noqa: BLE001 — failures are the signal here
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[dryrun] {arch} × {shape} × {mesh_kind}: FAIL {rec['error']}")
    return rec


def _mem_dict(mem) -> dict:
    out = {}
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr.replace("_size_in_bytes", "")] = int(v)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, choices=sorted(ARCHS) + [None])
    ap.add_argument("--shape", default=None, choices=sorted(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true", help="every (arch × shape)")
    ap.add_argument("--index-cell", action="store_true",
                    help="dry-run the sharded UG search step instead")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args(argv)

    cells = []
    if args.index_cell:
        cells = [(None, "index", args.mesh)]
    elif args.all:
        cells = [(a, s, args.mesh) for a in sorted(ARCHS) for s in SHAPES]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required (or --all / --index-cell)")
        cells = [(args.arch, args.shape, args.mesh)]

    failures = 0
    for arch, shape, mesh_kind in cells:
        rec = run_cell(arch or "", shape, mesh_kind, index_cell=args.index_cell)
        if args.out:
            p = pathlib.Path(args.out)
            p.parent.mkdir(parents=True, exist_ok=True)
            with p.open("a") as f:
                f.write(json.dumps(rec) + "\n")
        failures += 0 if rec.get("ok") else 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
