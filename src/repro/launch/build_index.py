"""Index-build CLI (the paper's offline indexing stage).

Builds a UG (or baseline) index over a synthetic corpus — or embeddings
produced by any --arch tower — and reports build time, memory and
self-test recall.

Example::

    PYTHONPATH=src python -m repro.launch.build_index --n 4000 --dim 32 \
        --out /tmp/ug_index
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp

from repro.core import Semantics, UGConfig, UGIndex, recall
from repro.data import CorpusConfig, make_corpus, make_queries


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ef-spatial", type=int, default=32)
    ap.add_argument("--ef-attribute", type=int, default=64)
    ap.add_argument("--max-edges", type=int, default=32)
    ap.add_argument("--iterations", type=int, default=3)
    ap.add_argument("--interval-mode", default="uniform", choices=["uniform", "point"])
    ap.add_argument("--prune-backend", default="auto",
                    choices=["auto", "pallas", "xla", "legacy"],
                    help="pruning-sweep kernel backend (auto = Pallas on TPU, "
                         "XLA on CPU); all three build bit-identical graphs")
    ap.add_argument("--dtype", default="f32",
                    choices=["f32", "bf16", "int8", "pq"],
                    help="vector scan plane (DESIGN.md §12/§14): bf16 halves "
                         "and int8 quarters the per-vector scan bytes; pq "
                         "product-quantizes to one byte per d/m-dim subspace; "
                         "the graph is always built from the f32 vectors")
    ap.add_argument("--rerank", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="attach the exact f32 rerank plane for final-top-k "
                         "re-scoring (default: on for int8/pq, off otherwise)")
    ap.add_argument("--out", default=None, help="directory to save the index")
    # store_true + default=True made --selftest a no-op (same pattern as the
    # launch/serve.py --reduced bug); BooleanOptionalAction restores
    # --no-selftest for build-only runs.
    ap.add_argument("--selftest", action=argparse.BooleanOptionalAction,
                    default=True)
    args = ap.parse_args(argv)

    ccfg = CorpusConfig(n=args.n, dim=args.dim, seed=args.seed,
                        interval_mode=args.interval_mode)
    x, ints = make_corpus(ccfg)
    cfg = UGConfig(
        ef_spatial=args.ef_spatial, ef_attribute=args.ef_attribute,
        max_edges_if=args.max_edges, max_edges_is=args.max_edges,
        iterations=args.iterations, exact_spatial=args.n <= 8192,
        prune_backend=None if args.prune_backend == "auto" else args.prune_backend,
    )
    idx = UGIndex.build(x, ints, cfg, progress=lambda m: print(f"[build] {m}"),
                        dtype=args.dtype, rerank=args.rerank)
    vm = idx.vector_memory_bytes()
    print(f"[build] done in {idx.build_seconds:.1f}s; "
          f"{idx.memory_bytes():,} graph bytes; "
          f"{args.dtype} plane {vm['plane']:,} bytes "
          f"({vm['plane_bytes_per_vector']:.1f} B/vec"
          f"{', +f32 rerank' if idx.store.rerank is not None else ''}); "
          f"degrees {idx.degree_stats()}")
    if args.out:
        idx.save(args.out)
        print(f"[build] saved to {args.out}")
    if args.selftest:
        qv, qi = make_queries(ccfg, 32)
        for sem in (Semantics.IF, Semantics.IS):
            res = idx.search(qv, qi, sem=sem, ef=64, k=10)
            gt = idx.ground_truth(qv, qi, sem=sem, k=10)
            print(f"[selftest] {sem.value} recall@10 = {recall(res, gt):.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
