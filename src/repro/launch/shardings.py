"""Sharding plans for dry-run / production steps (DESIGN.md §4).

Parameters get their 2-D (fsdp × tp) specs from the model's logical axes;
this module adds the *step-level* plans: batch specs, optimizer-state specs,
and decode-state specs (KV caches etc.), including the long-context rule —
when the request batch cannot be sharded over the data axes (B=1 long_500k),
the cache's **sequence** axis is sharded there instead and XLA's partial
softmax handles the distributed flash-decode merge.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig, batch_spec
from repro.models import encdec, rwkv_model, transformer, zamba


def _dp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _dp_size(mesh: Mesh) -> int:
    return math.prod(mesh.shape[a] for a in _dp_axes(mesh))


def _tp_ok(mesh: Mesh, dim: int) -> bool:
    return "model" in mesh.axis_names and dim % mesh.shape["model"] == 0


def batch_shardings(mesh: Mesh):
    return NamedSharding(mesh, batch_spec(mesh))


def _kv_plan(cfg: ModelConfig, mesh: Mesh, B: int, S: int, kv_heads: int):
    """Decide (bdim, sdim, kvdim) for a (L, B, S, KV, hd) cache.

    Preference order: batch over the data axes, heads over the model axis;
    every mesh axis that can't be used there lands on the **sequence** axis
    (distributed flash-decode: XLA's partial softmax merges the shards).
    """
    dp = _dp_axes(mesh)
    dpsz = _dp_size(mesh)
    tp = mesh.shape.get("model", 1)
    spare = []
    if B % dpsz == 0 and dpsz > 1:
        bdim = dp
    else:
        bdim = None
        spare.extend(dp)
    if kv_heads % tp == 0 and tp > 1:
        kvdim = "model"
    else:
        kvdim = None
        spare.append("model")
    spare = [a for a in spare if a in mesh.axis_names]
    ssz = math.prod(mesh.shape[a] for a in spare) if spare else 1
    sdim = tuple(spare) if spare and S % ssz == 0 else None
    return bdim, sdim, kvdim


def decode_state_specs(cfg: ModelConfig, mesh: Mesh, B: int, S: int):
    """PartitionSpec pytree matching ``registry.decode_state_specs``."""
    dp = _dp_axes(mesh)
    dpsz = _dp_size(mesh)
    b_ok = B % dpsz == 0 and dpsz > 1
    bdim = dp if b_ok else None
    blen = P(dp) if b_ok else P()

    if cfg.family == "decoder":
        if cfg.mla:
            # latent cache has no head axis: all spare capacity on S
            bd, sd, _ = _kv_plan(cfg, mesh, B, S, kv_heads=1)
            c = P(None, bd, sd, None)
            r = P(None, bd, sd, None)
            return transformer.DecodeState((c, r), blen)
        bd, sd, kvd = _kv_plan(cfg, mesh, B, S, cfg.n_kv_heads)
        kv = P(None, bd, sd, kvd, None)
        return transformer.DecodeState((kv, kv), blen)

    if cfg.family == "rwkv6":
        H = cfg.n_heads if cfg.n_heads else cfg.d_model // 64
        h_tp = "model" if _tp_ok(mesh, H) else None
        d_tp = "model" if _tp_ok(mesh, cfg.d_model) else None
        return rwkv_model.RwkvState(
            P(None, bdim, h_tp, None, None),
            P(None, bdim, None, d_tp),
            P(None, bdim, None, d_tp),
            blen,
        )

    if cfg.family == "zamba2":
        di = 2 * cfg.d_model
        H = di // 64
        h_tp = "model" if _tp_ok(mesh, H) else None
        ch_tp = "model" if _tp_ok(mesh, di + 2 * cfg.ssm_state) else None
        bd, sd, kvd = _kv_plan(cfg, mesh, B, S, cfg.n_kv_heads)
        kv = P(None, bd, sd, kvd, None)
        return zamba.ZambaState(
            P(None, bdim, h_tp, None, None),
            P(None, bdim, None, ch_tp),
            (kv, kv),
            blen,
        )

    if cfg.family == "encdec":
        bd, sd, kvd = _kv_plan(cfg, mesh, B, S, cfg.n_kv_heads)
        kv = P(None, bd, sd, kvd, None)
        xkv = P(None, bd, None, kvd, None)
        return encdec.EncDecState((kv, kv), (xkv, xkv), blen)

    raise ValueError(cfg.family)


def decode_state_shardings(cfg: ModelConfig, mesh: Mesh, B: int, S: int):
    specs = decode_state_specs(cfg, mesh, B, S)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def token_sharding(mesh: Mesh, B: int):
    dp = _dp_axes(mesh)
    ok = B % _dp_size(mesh) == 0
    return NamedSharding(mesh, P(dp if ok else None, None))
