"""Shared model substrate: configs, parameter builders, logical-axis sharding.

Parameters are plain pytrees (nested dicts of ``jnp`` arrays).  Every leaf is
created through a :class:`ParamBuilder` callback that records the *logical
axes* of each dimension (``"embed"``, ``"heads"``, ``"mlp"``, ``"vocab"``,
``"expert"``, ``"layers"`` …).  Logical axes are resolved to mesh axes by
:func:`resolve_spec` with divisibility checks — a dimension that does not
divide over its mesh axes is transparently replicated (e.g. kv_heads=4 on a
16-way model axis).  The same builder runs in three modes:

* ``init``  — materialize arrays (smoke tests, real training);
* ``shape`` — ``jax.eval_shape`` for allocation-free dry-runs;
* ``spec``  — produce the matching ``PartitionSpec`` tree.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config covers all 10 assigned architectures via ``family``."""

    name: str = "model"
    family: str = "decoder"          # decoder | encdec | rwkv6 | zamba2
    n_layers: int = 12
    d_model: int = 1024
    n_heads: int = 16
    n_kv_heads: int = 16
    d_ff: int = 4096
    vocab: int = 32000
    head_dim: int = 0                # 0 -> d_model // n_heads

    # mlp options
    gated_mlp: bool = True           # False: plain GELU MLP (starcoder2)

    # attention options
    qkv_bias: bool = False           # qwen1.5
    qk_norm: bool = False            # qwen3 / chameleon
    rope_theta: float = 10_000.0

    # MLA (minicpm3)
    mla: bool = False
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    rope_head_dim: int = 32

    # MoE (qwen3-moe, llama4)
    moe: bool = False
    n_experts: int = 0
    top_k: int = 1
    moe_d_ff: int = 0
    n_shared_experts: int = 0        # llama4 shared expert
    moe_every: int = 1               # llama4: MoE every k-th layer, dense otherwise
    dense_d_ff: int = 0              # d_ff of the interleaved dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2

    # SSM (rwkv6 / zamba2-mamba2)
    ssm_state: int = 64
    ssm_chunk: int = 64
    attn_every: int = 6              # zamba2: shared attn block period

    # enc-dec (seamless-m4t)
    enc_layers: int = 0

    # numerics / structure
    dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    remat: bool = True
    logits_chunk: int = 512          # chunked cross-entropy (DESIGN.md §3)
    scan_layers: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Total parameter count N (for MODEL_FLOPS = 6·N·D roofline term)."""
        shapes = init_params(self, mode="shape")
        return sum(
            int(math.prod(l.shape)) for l in jax.tree_util.tree_leaves(shapes)
        )

    def active_param_count(self) -> int:
        """Active-per-token N for MoE (6·N_active·D); == N for dense."""
        if not self.moe:
            return self.param_count()
        total = self.param_count()
        shapes = init_params(self, mode="shape")
        expert_leaves = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
            if any("experts" in str(p) for p in path):
                expert_leaves += int(math.prod(leaf.shape))
        active_frac = self.top_k / max(self.n_experts, 1)
        return int(total - expert_leaves + expert_leaves * active_frac)


# ---------------------------------------------------------------------------
# Logical-axis resolution
# ---------------------------------------------------------------------------
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "embed": ("pod", "data"),        # FSDP shard of the contraction dim
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "expert": ("model",),
    "layers": (),
    "seq": (),
    "state": (),
    "rank": (),
    "hd": (),
}


def resolve_axis(
    logical: str | None, dim: int, mesh_shape: Mapping[str, int],
    rules: Mapping[str, tuple[str, ...]],
) -> tuple[str, ...] | None:
    """Map one logical axis to mesh axes, dropping non-divisible shards."""
    if logical is None:
        return None
    axes = tuple(a for a in rules.get(logical, ()) if a in mesh_shape)
    if not axes:
        return None
    size = math.prod(mesh_shape[a] for a in axes)
    if dim % size == 0:
        return axes
    # try a prefix that divides (keeps at least partial sharding)
    for cut in range(len(axes) - 1, 0, -1):
        size = math.prod(mesh_shape[a] for a in axes[:cut])
        if dim % size == 0:
            return axes[:cut]
    return None


def resolve_spec(
    shape: Sequence[int], axes: Sequence[str | None],
    mesh_shape: Mapping[str, int], rules: Mapping[str, tuple[str, ...]],
) -> P:
    assert len(shape) == len(axes), (shape, axes)
    used: set[str] = set()
    out = []
    for dim, ax in zip(shape, axes):
        r = resolve_axis(ax, dim, mesh_shape, rules)
        if r is None or any(a in used for a in r):
            out.append(None)
        else:
            used.update(r)
            out.append(r if len(r) > 1 else r[0])
    return P(*out)


# ---------------------------------------------------------------------------
# Parameter builder
# ---------------------------------------------------------------------------
class ParamBuilder:
    """Records (shape, logical axes, init) per leaf; see module docstring."""

    def __init__(self, cfg: ModelConfig, mode: str, key: jax.Array | None = None,
                 mesh: Mesh | None = None,
                 rules: Mapping[str, tuple[str, ...]] | None = None):
        assert mode in ("init", "shape", "spec")
        self.cfg = cfg
        self.mode = mode
        self.key = key
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)

    def _next_key(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    def __call__(self, shape: Sequence[int], axes: Sequence[str | None],
                 init: str = "normal", scale: float | None = None):
        shape = tuple(int(s) for s in shape)
        if self.mode == "spec":
            ms = {a: s for a, s in zip(self.mesh.axis_names, self.mesh.devices.shape)}
            return resolve_spec(shape, axes, ms, self.rules)
        dtype = self.cfg.dtype
        if self.mode == "shape":
            return jax.ShapeDtypeStruct(shape, dtype)
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        s = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(self._next_key(), shape, jnp.float32) * s).astype(dtype)


# ---------------------------------------------------------------------------
# Core layers (functional)
# ---------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * scale).astype(x.dtype) * gamma


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding over the last axis; x (..., S, H, hd), positions (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    rx1 = x1 * cos - x2 * sin
    rx2 = x2 * cos + x1 * sin
    return jnp.concatenate([rx1, rx2], axis=-1).astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


def init_params(cfg: ModelConfig, mode: str = "init", key=None, mesh=None, rules=None):
    """Dispatch to the family-specific parameter builder."""
    from repro.models import encdec, ssm, transformer, zamba

    b = ParamBuilder(cfg, mode, key=key, mesh=mesh, rules=rules)
    if cfg.family == "decoder":
        return transformer.build_params(cfg, b)
    if cfg.family == "encdec":
        return encdec.build_params(cfg, b)
    if cfg.family == "rwkv6":
        return ssm.build_rwkv6_params(cfg, b)
    if cfg.family == "zamba2":
        return zamba.build_params(cfg, b)
    raise ValueError(f"unknown family {cfg.family}")


def param_specs(cfg: ModelConfig, mesh: Mesh, rules=None):
    return init_params(cfg, mode="spec", mesh=mesh, rules=rules)


def param_shardings(cfg: ModelConfig, mesh: Mesh, rules=None):
    specs = param_specs(cfg, mesh, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh: Mesh) -> P:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(axes if len(axes) > 1 else (axes[0] if axes else None))
