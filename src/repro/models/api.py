"""Model API: one uniform surface over the four architecture families.

``Model`` bundles the family-dispatched functions every launcher needs:
``init``/``loss``/``forward`` (train path), ``prefill``/``decode_step``/
``init_decode_state`` (serve path), plus the input pytrees for each assigned
input shape (real arrays for smoke tests, ShapeDtypeStructs for dry-runs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import encdec, rwkv_model, transformer, zamba
from repro.models.common import ModelConfig, init_params, param_shardings, param_specs


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------- params
    def init(self, key) -> Any:
        return init_params(self.cfg, mode="init", key=key)

    def shapes(self) -> Any:
        return init_params(self.cfg, mode="shape")

    def specs(self, mesh, rules=None):
        return param_specs(self.cfg, mesh, rules)

    def shardings(self, mesh, rules=None):
        return param_shardings(self.cfg, mesh, rules)

    # ------------------------------------------------------------- train
    def loss(self, params, batch):
        f = {
            "decoder": transformer.loss_fn,
            "encdec": encdec.loss_fn,
            "rwkv6": rwkv_model.loss_fn,
            "zamba2": zamba.loss_fn,
        }[self.cfg.family]
        return f(self.cfg, params, batch)

    def forward(self, params, tokens, **kw):
        f = {
            "decoder": transformer.forward,
            "rwkv6": rwkv_model.forward,
            "zamba2": zamba.forward,
        }[self.cfg.family]
        return f(self.cfg, params, tokens, **kw)

    # ------------------------------------------------------------- serve
    def prefill(self, params, batch):
        cfg = self.cfg
        if cfg.family == "decoder":
            hidden, caches = transformer.prefill(cfg, params, batch["tokens"])
            return hidden, caches
        if cfg.family == "encdec":
            enc_out = encdec.encode(cfg, params, batch["frames"])
            hidden = encdec.decode_train(cfg, params, batch["tokens"], enc_out)
            return hidden, None
        if cfg.family == "rwkv6":
            hidden, _, _ = rwkv_model.forward(cfg, params, batch["tokens"])
            return hidden, None
        if cfg.family == "zamba2":
            hidden, _, caches = zamba.forward(cfg, params, batch["tokens"], collect_cache=True)
            return hidden, caches
        raise ValueError(cfg.family)

    def init_decode_state(self, params_or_batch, batch_size: int, max_len: int):
        cfg = self.cfg
        if cfg.family == "decoder":
            return transformer.init_cache(cfg, batch_size, max_len)
        if cfg.family == "rwkv6":
            return rwkv_model.init_state(cfg, batch_size, max_len)
        if cfg.family == "zamba2":
            return zamba.init_state(cfg, batch_size, max_len)
        if cfg.family == "encdec":
            # needs encoder frames: params_or_batch is (params, frames)
            params, frames = params_or_batch
            return encdec.init_state(cfg, params, frames, batch_size, max_len)
        raise ValueError(cfg.family)

    def decode_step(self, params, state, tokens):
        f = {
            "decoder": transformer.decode_step,
            "encdec": encdec.decode_step,
            "rwkv6": rwkv_model.decode_step,
            "zamba2": zamba.decode_step,
        }[self.cfg.family]
        return f(self.cfg, params, state, tokens)


def get_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
