"""Encoder-decoder transformer (seamless-m4t backbone).

The audio frontend is a STUB per the harness instruction: ``input_specs()``
provides precomputed frame embeddings (B, S_enc, d_model) directly to the
encoder.  The decoder is a standard causal stack with cross-attention whose
K/V come from the encoder output (cached once at prefill).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import shard_ctx
from repro.models.common import ModelConfig, rms_norm, swiglu
from repro.models.transformer import lm_loss, unembed


def _ffn_params(cfg, b, L, lax_):
    return {
        "w_gate": b(L + (cfg.d_model, cfg.d_ff), lax_ + ("embed", "mlp")),
        "w_up": b(L + (cfg.d_model, cfg.d_ff), lax_ + ("embed", "mlp")),
        "w_down": b(L + (cfg.d_ff, cfg.d_model), lax_ + ("mlp", "embed")),
    }


def build_params(cfg: ModelConfig, b):
    enc_l = cfg.enc_layers or cfg.n_layers
    Le, Ld = (enc_l,), (cfg.n_layers,)
    lax_ = ("layers",)
    enc = {
        "ln1": b(Le + (cfg.d_model,), lax_ + ("embed",), init="ones"),
        "attn": {
            **{k: v for k, v in attn.build_gqa_params(
                dataclasses_replace(cfg, n_layers=enc_l), b).items()},
        },
        "ln2": b(Le + (cfg.d_model,), lax_ + ("embed",), init="ones"),
        "mlp": _ffn_params(cfg, b, Le, lax_),
    }
    dec = {
        "ln1": b(Ld + (cfg.d_model,), lax_ + ("embed",), init="ones"),
        "self_attn": attn.build_gqa_params(cfg, b),
        "ln_x": b(Ld + (cfg.d_model,), lax_ + ("embed",), init="ones"),
        "cross_attn": attn.build_gqa_params(cfg, b),
        "ln2": b(Ld + (cfg.d_model,), lax_ + ("embed",), init="ones"),
        "mlp": _ffn_params(cfg, b, Ld, lax_),
    }
    return {
        "frame_proj": b((cfg.d_model, cfg.d_model), ("embed", "mlp")),
        "embed": b((cfg.vocab, cfg.d_model), ("vocab", "embed"), scale=0.02),
        "encoder": enc,
        "decoder": dec,
        "ln_enc": b((cfg.d_model,), ("embed",), init="ones"),
        "ln_f": b((cfg.d_model,), ("embed",), init="ones"),
        "unembed": b((cfg.d_model, cfg.vocab), ("embed", "vocab")),
    }


def dataclasses_replace(cfg, **kw):
    import dataclasses

    return dataclasses.replace(cfg, **kw)


def _maybe_remat(cfg, fn):
    if cfg.remat:
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return fn


def encode(cfg: ModelConfig, params, frames):
    """frames (B, S_enc, d_model) -> encoder output (B, S_enc, d_model)."""
    x = jnp.einsum("bsd,de->bse", frames.astype(cfg.dtype), params["frame_proj"])
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def blk(xx, p_l):
        xx = shard_ctx.constrain(xx, ("dp", "tp", None))
        h = rms_norm(xx, p_l["ln1"], cfg.norm_eps)
        a, _ = attn.gqa_attend(cfg, p_l["attn"], h, positions, causal=False)
        xx = xx + a
        h = rms_norm(xx, p_l["ln2"], cfg.norm_eps)
        return xx + swiglu(h, p_l["mlp"]["w_gate"], p_l["mlp"]["w_up"], p_l["mlp"]["w_down"])

    body = _maybe_remat(cfg, blk)
    x, _ = jax.lax.scan(lambda xx, pl: (body(xx, pl), 0), x, params["encoder"])
    out = rms_norm(x, params["ln_enc"], cfg.norm_eps)
    return shard_ctx.constrain(out, ("dp", None, None))


def _dec_block(cfg, p_l, x, positions, enc_kv, self_cache=None, cache_len=None):
    if self_cache is None:
        x = shard_ctx.constrain(x, ("dp", "tp", None))
    h = rms_norm(x, p_l["ln1"], cfg.norm_eps)
    if self_cache is None:
        a, kv = attn.gqa_attend(cfg, p_l["self_attn"], h, positions, causal=True)
    else:
        a, kv = attn.gqa_attend(
            cfg, p_l["self_attn"], h, positions, cache=self_cache, cache_len=cache_len
        )
    x = x + a
    h = rms_norm(x, p_l["ln_x"], cfg.norm_eps)
    ca, _ = attn.gqa_attend(cfg, p_l["cross_attn"], h, positions, causal=False, kv=enc_kv)
    x = x + ca
    h = rms_norm(x, p_l["ln2"], cfg.norm_eps)
    x = x + swiglu(h, p_l["mlp"]["w_gate"], p_l["mlp"]["w_up"], p_l["mlp"]["w_down"])
    return x, kv


def cross_kv(cfg: ModelConfig, params, enc_out):
    """Precompute per-layer cross-attention K/V from the encoder output."""
    B, S, _ = enc_out.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def one(p_l):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p_l["cross_attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p_l["cross_attn"]["wv"])
        if cfg.qkv_bias:
            k = k + p_l["cross_attn"]["bk"]
            v = v + p_l["cross_attn"]["bv"]
        if cfg.qk_norm:
            k = rms_norm(k, p_l["cross_attn"]["k_norm"], cfg.norm_eps)
        from repro.models.common import rope

        k = rope(k, positions, cfg.rope_theta)
        k = shard_ctx.constrain(k, ("dp", None, "tp", None))
        v = shard_ctx.constrain(v, ("dp", None, "tp", None))
        return k, v

    return jax.vmap(one)(params["decoder"])


def decode_train(cfg: ModelConfig, params, tokens, enc_out):
    x = params["embed"][tokens]
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    enc_kvs = cross_kv(cfg, params, enc_out)

    body = _maybe_remat(
        cfg, lambda xx, p_l, ekv: _dec_block(cfg, p_l, xx, positions, ekv)[0]
    )
    def scan_fn(xx, inp):
        p_l, ekv = inp
        return body(xx, p_l, ekv), 0

    x, _ = jax.lax.scan(scan_fn, x, (params["decoder"], enc_kvs))
    return rms_norm(x, params["ln_f"], cfg.norm_eps)


def loss_fn(cfg: ModelConfig, params, batch):
    enc_out = encode(cfg, params, batch["frames"])
    hidden = decode_train(cfg, params, batch["tokens"], enc_out)
    ce = lm_loss(cfg, params, hidden, batch["labels"], batch["mask"])
    return ce, {"ce": ce, "aux": 0.0}


class EncDecState(NamedTuple):
    self_cache: Any
    enc_kvs: Any
    cache_len: jnp.ndarray


def init_state(cfg: ModelConfig, params, frames, batch: int, max_len: int):
    enc_out = encode(cfg, params, frames)
    enc_kvs = cross_kv(cfg, params, enc_out)
    kv_shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    cache = (jnp.zeros(kv_shape, cfg.dtype), jnp.zeros(kv_shape, cfg.dtype))
    return EncDecState(cache, enc_kvs, jnp.zeros((batch,), jnp.int32))


def decode_step(cfg: ModelConfig, params, state: EncDecState, tokens):
    x = params["embed"][tokens]
    positions = state.cache_len[:, None]

    def scan_fn(xx, inp):
        p_l, cache_l, ekv = inp
        h = rms_norm(xx, p_l["ln1"], cfg.norm_eps)
        a, new_cache = attn.gqa_attend(
            cfg, p_l["self_attn"], h, positions, cache=cache_l, cache_len=state.cache_len
        )
        xx = xx + a
        h = rms_norm(xx, p_l["ln_x"], cfg.norm_eps)
        ca, _ = attn.gqa_attend(cfg, p_l["cross_attn"], h, positions, causal=False, kv=ekv)
        xx = xx + ca
        h = rms_norm(xx, p_l["ln2"], cfg.norm_eps)
        xx = xx + swiglu(h, p_l["mlp"]["w_gate"], p_l["mlp"]["w_up"], p_l["mlp"]["w_down"])
        return xx, new_cache

    x, new_cache = jax.lax.scan(scan_fn, x, (params["decoder"], state.self_cache, state.enc_kvs))
    h = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = unembed(cfg, params, h)[:, 0]
    return EncDecState(new_cache, state.enc_kvs, state.cache_len + 1), logits
