"""Model zoo: the 10 assigned architectures as one configurable family set."""
from repro.models.api import Model, get_model
from repro.models.common import ModelConfig, init_params, param_shardings, param_specs

__all__ = ["Model", "get_model", "ModelConfig", "init_params",
           "param_shardings", "param_specs"]
