"""Mixture-of-Experts FFN: top-k router + shard-local capacity dispatch.

The dispatch is grouped by data shard so every sort/scatter stays local
under SPMD: tokens are reshaped ``(T,) -> (G, T/G)`` with ``G`` = the
data-parallel degree and the group axis pinned to the data axes — a global
argsort over tokens would otherwise become a cross-device sort (measured:
11 TB of collectives per step on qwen3-moe before this reformulation).

Expert compute runs as a ``lax.scan`` over expert blocks (block axis sharded
over the ``model`` axis) so the transient dispatch buffers are bounded by
``E/blocks`` regardless of expert count; the only cross-model traffic is the
one combine all-reduce per layer (activation-sized, same as dense TP).

Router aux loss follows Switch (load-balance: E · Σ_e f_e · p_e).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import shard_ctx
from repro.models.common import ModelConfig

from repro.compat import shard_map


def build_moe_params(cfg: ModelConfig, b, prefix_layers: bool = True):
    L = (cfg.n_layers,) if prefix_layers else ()
    lax_ = ("layers",) if prefix_layers else ()
    dff = cfg.moe_d_ff or cfg.d_ff
    p = {
        "router": b(L + (cfg.d_model, cfg.n_experts), lax_ + ("embed", "expert")),
        "experts": {
            "w_gate": b(L + (cfg.n_experts, cfg.d_model, dff), lax_ + ("expert", "embed", "mlp")),
            "w_up": b(L + (cfg.n_experts, cfg.d_model, dff), lax_ + ("expert", "embed", "mlp")),
            "w_down": b(L + (cfg.n_experts, dff, cfg.d_model), lax_ + ("expert", "mlp", "embed")),
        },
    }
    if cfg.n_shared_experts:
        sdff = dff * cfg.n_shared_experts
        p["shared"] = {
            "w_gate": b(L + (cfg.d_model, sdff), lax_ + ("embed", "mlp")),
            "w_up": b(L + (cfg.d_model, sdff), lax_ + ("embed", "mlp")),
            "w_down": b(L + (sdff, cfg.d_model), lax_ + ("mlp", "embed")),
        }
    return p


def _local_dispatch(xt, gate_idx, gate_vals, E: int, C: int):
    """Sort-based capacity dispatch over one token block (pure local math).

    xt (T, d); gate_idx/vals (T, K).  Returns (buf (E, C, d),
    t_of_slot (E, C), w_of_slot (E, C)) — slot maps for the combine.
    """
    T, K = gate_idx.shape
    N = T * K
    flat_e = gate_idx.reshape(N)
    flat_t = jnp.broadcast_to(
        jnp.arange(T, dtype=jnp.int32)[:, None], (T, K)
    ).reshape(N)
    flat_w = gate_vals.reshape(N)
    order = jnp.argsort(flat_e, stable=True)
    e_s = flat_e[order]
    t_s = flat_t[order]
    w_s = flat_w[order]
    first = jnp.searchsorted(e_s, e_s, side="left")
    rank = (jnp.arange(N, dtype=jnp.int32) - first).astype(jnp.int32)
    keep = rank < C
    e_ix = jnp.where(keep, e_s, E)
    r_ix = jnp.where(keep, rank, 0)
    buf = jnp.zeros((E + 1, C, xt.shape[-1]), xt.dtype)
    buf = buf.at[e_ix, r_ix].set(xt[t_s], mode="drop")[:E]
    t_of = jnp.zeros((E + 1, C), jnp.int32).at[e_ix, r_ix].set(t_s, mode="drop")[:E]
    w_of = jnp.zeros((E + 1, C), jnp.float32).at[e_ix, r_ix].set(
        jnp.where(keep, w_s, 0.0), mode="drop"
    )[:E]
    return buf, t_of, w_of


def _router(cfg: ModelConfig, xt, router_w):
    """Top-k routing + Switch aux terms.  xt (T, d), router_w (d, E)."""
    logits = jnp.einsum(
        "td,de->te", xt.astype(jnp.float32), router_w.astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, cfg.top_k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    frac = jnp.mean(
        jax.nn.one_hot(gate_idx[..., 0], cfg.n_experts, dtype=jnp.float32), axis=0
    )
    mean_p = jnp.mean(probs, axis=0)
    return gate_idx, gate_vals, frac, mean_p


def _moe_ffn_ep(cfg: ModelConfig, p, x: jnp.ndarray):
    """Expert-parallel MoE via shard_map (DESIGN.md §4; the EP baseline).

    Tokens arrive (batch × sequence)-sharded over every mesh axis — the
    residual stream is already (dp, tp)-sharded — so each device dispatches
    only its own tokens; two all-to-alls over the ``model`` axis move token
    slots to/from expert owners; expert weights' fsdp shards are all-gathered
    once per layer.  Measured vs the auto-SPMD global dispatch this is a
    ~50× collective-byte reduction (EXPERIMENTS.md §Perf).
    """
    from jax.sharding import PartitionSpec as P

    mesh = shard_ctx._MESH
    axes = set(mesh.axis_names)
    dp_axes = tuple(a for a in ("pod", "data") if a in axes)
    tp_axes = ("model",) if "model" in axes else ()
    all_axes = dp_axes + tp_axes
    sizes = dict(mesh.shape)
    tp = sizes.get("model", 1)
    n_shards = 1
    for a in all_axes:
        n_shards *= sizes[a]

    B, S, d = x.shape
    T = B * S
    T_dev = T // n_shards
    E, K = cfg.n_experts, cfg.top_k
    dff = cfg.moe_d_ff or cfg.d_ff
    E_loc = E // tp
    C = min(max(int(T_dev * K / max(E, 1) * cfg.capacity_factor) + 1, 4), T_dev * K)

    has_shared = bool(cfg.n_shared_experts)

    def local_fn(x_l, router_l, wg_l, wu_l, wd_l, *shared_l):
        # x_l is exactly this device's residual shard (B_loc, S_loc, d):
        # the block stream is (dp, tp)-sharded, so entering the MoE costs
        # zero data movement.
        B_loc, S_loc, _ = x_l.shape
        xt_l = x_l.reshape(B_loc * S_loc, d)                    # (T_dev, d)
        router_w = router_l
        if dp_axes:
            router_w = jax.lax.all_gather(router_w, dp_axes, axis=0, tiled=True)
        if tp > 1:
            router_w = jax.lax.all_gather(router_w, "model", axis=1, tiled=True)

        gate_idx, gate_vals, frac, mean_p = _router(cfg, xt_l, router_w)
        aux_f = jax.lax.pmean(frac, all_axes)
        aux_p = jax.lax.pmean(mean_p, all_axes)
        aux = E * jnp.sum(aux_f * aux_p) * cfg.router_aux_weight

        buf, t_of, w_of = _local_dispatch(xt_l, gate_idx, gate_vals, E, C)

        # ---- all-to-all: send expert slices to their owners ----
        if tp > 1:
            send = buf.reshape(tp, E_loc, C, d)
            recv = jax.lax.all_to_all(send, "model", split_axis=0, concat_axis=0)
            tok_in = jnp.moveaxis(recv, 0, 1).reshape(E_loc, tp * C, d)
        else:
            tok_in = buf

        # ---- expert FFN (weights' fsdp shards gathered once) ----
        wg = jax.lax.all_gather(wg_l, dp_axes, axis=1, tiled=True) if dp_axes else wg_l
        wu = jax.lax.all_gather(wu_l, dp_axes, axis=1, tiled=True) if dp_axes else wu_l
        wd = jax.lax.all_gather(wd_l, dp_axes, axis=2, tiled=True) if dp_axes else wd_l
        hg = jnp.einsum("ecd,edf->ecf", tok_in, wg)
        hu = jnp.einsum("ecd,edf->ecf", tok_in, wu)
        h = jax.nn.silu(hg.astype(jnp.float32)).astype(x.dtype) * hu
        y_sl = jnp.einsum("ecf,efd->ecd", h, wd)                # (E_loc, tp*C, d)

        # ---- all-to-all back + local combine ----
        if tp > 1:
            back = jnp.moveaxis(y_sl.reshape(E_loc, tp, C, d), 1, 0)
            mine = jax.lax.all_to_all(back, "model", split_axis=0, concat_axis=0)
            y_all = mine.reshape(E, C, d)
        else:
            y_all = y_sl
        contrib = y_all * w_of[..., None].astype(x.dtype)
        y_tok = jnp.zeros((B_loc * S_loc, d), x.dtype).at[t_of].add(contrib)

        if has_shared:
            # tokens are split over the model axis too, so every device
            # needs the FULL shared-expert weights for its own tokens (an
            # f-shard + psum would mix different tokens' partials).
            swg, swu, swd = shared_l
            if dp_axes:
                swg = jax.lax.all_gather(swg, dp_axes, axis=0, tiled=True)
                swu = jax.lax.all_gather(swu, dp_axes, axis=0, tiled=True)
                swd = jax.lax.all_gather(swd, dp_axes, axis=1, tiled=True)
            if tp > 1:
                swg = jax.lax.all_gather(swg, "model", axis=1, tiled=True)
                swu = jax.lax.all_gather(swu, "model", axis=1, tiled=True)
                swd = jax.lax.all_gather(swd, "model", axis=0, tiled=True)
            g = jnp.einsum("td,df->tf", xt_l, swg)
            u = jnp.einsum("td,df->tf", xt_l, swu)
            y_tok = y_tok + jnp.einsum(
                "tf,fd->td",
                jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u,
                swd,
            )

        return y_tok.reshape(B_loc, S_loc, d), aux

    row0 = P(dp_axes, "model" if tp > 1 else None, None)   # residual layout
    tp_dim = "model" if tp > 1 else None
    specs_in = [
        row0,                              # x (B, S, d)
        P(dp_axes, tp_dim),                # router (d, E)
        P(tp_dim, dp_axes, None),          # w_gate (E, d, f)
        P(tp_dim, dp_axes, None),          # w_up
        P(tp_dim, None, dp_axes),          # w_down (E, f, d)
    ]
    args = [x, p["router"], p["experts"]["w_gate"], p["experts"]["w_up"],
            p["experts"]["w_down"]]
    if has_shared:
        specs_in += [P(dp_axes, tp_dim), P(dp_axes, tp_dim), P(tp_dim, dp_axes)]
        args += [p["shared"]["w_gate"], p["shared"]["w_up"], p["shared"]["w_down"]]

    fn = shard_map(
        local_fn, mesh=mesh, in_specs=tuple(specs_in),
        out_specs=(row0, P()), check_vma=False,
    )
    x_in = shard_ctx.constrain(x, ("dp", "tp", None))
    y, aux = fn(x_in, *args[1:])
    # pin the output back to the residual stream's (dp, tp) layout so the
    # gradient accumulate doesn't force an involuntary replication (XLA
    # spmd_partitioner warning otherwise).
    y = shard_ctx.constrain(y, ("dp", "tp", None))
    return y, jnp.mean(aux)


def moe_ffn(cfg: ModelConfig, p, x: jnp.ndarray):
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    if shard_ctx.active():
        B, S, d = x.shape
        n_shards = shard_ctx.dp_size() * shard_ctx.tp_size()
        dpsz, tpsz = shard_ctx.dp_size(), shard_ctx.tp_size()
        if (
            dpsz * tpsz > 1
            and B % max(dpsz, 1) == 0
            and S % max(tpsz, 1) == 0
            and cfg.n_experts % max(tpsz, 1) == 0
            and (B * S) // (dpsz * tpsz) >= 4
        ):
            return _moe_ffn_ep(cfg, p, x)
    return _moe_ffn_local(cfg, p, x)


def _moe_ffn_local(cfg: ModelConfig, p, x: jnp.ndarray):
    """Single-shard (or fallback) path: same math, no collectives."""
    B, S, d = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    dff = cfg.moe_d_ff or cfg.d_ff
    G = shard_ctx.dp_size()
    if G <= 0 or T % G:
        G = 1
    Tg = T // G

    xt = shard_ctx.constrain(x.reshape(G, Tg, d), ("dp", None, None))

    logits = jnp.einsum(
        "gtd,de->gte", xt.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)            # (G, Tg, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch aux load-balance loss (global means).
    dispatch_frac = jnp.mean(
        jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32), axis=(0, 1)
    )
    aux = E * jnp.sum(dispatch_frac * jnp.mean(probs, axis=(0, 1))) * cfg.router_aux_weight

    # ---- shard-local sort-based dispatch (per group g) ----
    C = min(max(int(Tg * K / max(E, 1) * cfg.capacity_factor) + 1, 4), Tg * K)
    N = Tg * K
    flat_e = gate_idx.reshape(G, N)
    flat_t = jnp.broadcast_to(
        jnp.arange(Tg, dtype=jnp.int32)[:, None], (Tg, K)
    ).reshape(1, N)
    flat_t = jnp.broadcast_to(flat_t, (G, N))
    flat_w = gate_vals.reshape(G, N)

    order = jnp.argsort(flat_e, axis=1, stable=True)
    e_s = jnp.take_along_axis(flat_e, order, axis=1)
    t_s = jnp.take_along_axis(flat_t, order, axis=1)
    w_s = jnp.take_along_axis(flat_w, order, axis=1)
    first = jax.vmap(lambda a: jnp.searchsorted(a, a, side="left"))(e_s)
    rank = jnp.arange(N, dtype=jnp.int32)[None, :] - first.astype(jnp.int32)
    keep = rank < C
    slot = jnp.where(keep, e_s * C + rank, E * C)            # E*C = drop bin
    g_ix = jnp.arange(G)[:, None]

    # Dispatch into (G, E, C, d) with the EXPERT axis model-sharded: the
    # tokens (replicated along the model axis within their data group) are
    # scattered by every model shard into just its expert slice — no
    # cross-shard dispatch traffic; XLA masks out-of-shard updates locally.
    e_ix = jnp.where(keep, e_s, E)
    r_ix = jnp.where(keep, rank, 0)
    x_sorted = jnp.take_along_axis(xt, t_s[..., None], axis=1)   # (G, N, d)
    g_ix3 = jnp.broadcast_to(g_ix, e_ix.shape)
    buf = jnp.zeros((G, E + 1, C, d), x.dtype)
    buf = buf.at[g_ix3, e_ix, r_ix].set(x_sorted, mode="drop")[:, :E]
    buf = shard_ctx.constrain(buf, ("dp", "tp", None, None))

    # slot -> (token, combine weight) inverse maps for the combine scatter
    t_of_slot = jnp.zeros((G, E + 1, C), jnp.int32).at[g_ix3, e_ix, r_ix].set(
        t_s, mode="drop"
    )[:, :E]
    w_of_slot = jnp.zeros((G, E + 1, C), jnp.float32).at[g_ix3, e_ix, r_ix].set(
        jnp.where(keep, w_s, 0.0), mode="drop"
    )[:, :E]

    # ---- expert compute: all experts at once, expert axis sharded ----
    hg = jnp.einsum("gecd,edf->gecf", buf, p["experts"]["w_gate"])
    hu = jnp.einsum("gecd,edf->gecf", buf, p["experts"]["w_up"])
    h = jax.nn.silu(hg.astype(jnp.float32)).astype(x.dtype) * hu
    y_slots = jnp.einsum("gecf,efd->gecd", h, p["experts"]["w_down"])
    y_slots = shard_ctx.constrain(y_slots, ("dp", "tp", None, None))

    # ---- combine: weighted scatter-add back to token order (one AR) ----
    contrib = y_slots * w_of_slot[..., None].astype(x.dtype)
    g_full = jnp.broadcast_to(jnp.arange(G)[:, None, None], t_of_slot.shape)
    y = jnp.zeros((G, Tg, d), x.dtype).at[g_full, t_of_slot].add(contrib)
    y = shard_ctx.constrain(y, ("dp", None, None))

    if cfg.n_shared_experts:
        sp = p["shared"]
        g = jnp.einsum("gtd,df->gtf", xt, sp["w_gate"])
        u = jnp.einsum("gtd,df->gtf", xt, sp["w_up"])
        y = y + jnp.einsum(
            "gtf,fd->gtd",
            jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u,
            sp["w_down"],
        )
    return y.reshape(B, S, d), aux
