"""Decoder-only transformer LM covering 8 of the 10 assigned archs
(dense GQA, qkv-bias, qk-norm, MLA, MoE, early-fusion VLM token streams).

Layers are *stacked* (leading ``n_layers`` dim) and applied with
``jax.lax.scan`` (+ optional ``jax.checkpoint``) so compile time is O(1) in
depth; losses use chunked cross-entropy so the (B, S, vocab) logits tensor
never materializes (vocab up to 256k — DESIGN.md §3).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import shard_ctx
from repro.models.common import ModelConfig, rms_norm, swiglu


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------
def _build_blocks(cfg: ModelConfig, b, n_layers: int, *, moe: bool, d_ff: int):
    L = (n_layers,)
    lax_ = ("layers",)
    import dataclasses as _dc

    cfg_l = _dc.replace(cfg, n_layers=n_layers)
    blocks: dict[str, Any] = {
        "ln1": b(L + (cfg.d_model,), lax_ + ("embed",), init="ones"),
        "ln2": b(L + (cfg.d_model,), lax_ + ("embed",), init="ones"),
    }
    if cfg.mla:
        blocks["attn"] = attn.build_mla_params(cfg_l, b)
    else:
        blocks["attn"] = attn.build_gqa_params(cfg_l, b)
    if moe:
        blocks["moe"] = moe_lib.build_moe_params(cfg_l, b)
    elif cfg.gated_mlp:
        blocks["mlp"] = {
            "w_gate": b(L + (cfg.d_model, d_ff), lax_ + ("embed", "mlp")),
            "w_up": b(L + (cfg.d_model, d_ff), lax_ + ("embed", "mlp")),
            "w_down": b(L + (d_ff, cfg.d_model), lax_ + ("mlp", "embed")),
        }
    else:  # plain 2-matrix GELU MLP (starcoder2 / GPT-BigCode style)
        blocks["mlp"] = {
            "w_up": b(L + (cfg.d_model, d_ff), lax_ + ("embed", "mlp")),
            "w_down": b(L + (d_ff, cfg.d_model), lax_ + ("mlp", "embed")),
        }
    return blocks


def build_params(cfg: ModelConfig, b):
    if cfg.moe and cfg.moe_every > 1:
        # llama4-style interleave: each "super layer" = (moe_every - 1) dense
        # blocks followed by one MoE block; scan runs over super layers.
        n_super = cfg.n_layers // cfg.moe_every
        blocks = _build_blocks(cfg, b, n_super, moe=True, d_ff=cfg.d_ff)
        dense = _build_blocks(
            cfg, b, n_super * (cfg.moe_every - 1), moe=False,
            d_ff=cfg.dense_d_ff or cfg.d_ff,
        )
    else:
        blocks = _build_blocks(cfg, b, cfg.n_layers, moe=cfg.moe, d_ff=cfg.d_ff)
        dense = None
    params = {
        "embed": b((cfg.vocab, cfg.d_model), ("vocab", "embed"), scale=0.02),
        "blocks": blocks,
        "ln_f": b((cfg.d_model,), ("embed",), init="ones"),
    }
    if dense is not None:
        params["dense_blocks"] = dense
    if not cfg.tie_embeddings:
        params["unembed"] = b((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    return params


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------
def _ffn(cfg: ModelConfig, p_l, h):
    # dispatch on the block's own params: interleaved configs (moe_every > 1)
    # mix dense and MoE blocks under one cfg.
    if "moe" in p_l:
        return moe_lib.moe_ffn(cfg, p_l["moe"], h)
    if "w_gate" not in p_l["mlp"]:
        u = jnp.einsum("...d,df->...f", h, p_l["mlp"]["w_up"])
        a = jax.nn.gelu(u.astype(jnp.float32)).astype(h.dtype)
        return jnp.einsum("...f,fd->...d", a, p_l["mlp"]["w_down"]), 0.0
    return swiglu(h, p_l["mlp"]["w_gate"], p_l["mlp"]["w_up"], p_l["mlp"]["w_down"]), 0.0


def block_train(cfg: ModelConfig, p_l, x, positions):
    """One decoder block, full-sequence causal.  Returns (x, aux, kv)."""
    # sequence-parallel residual stream: the saved scan carry is sharded
    # (batch over dp, sequence over tp) so per-layer saved activations
    # shrink by the TP degree; attention/FFN re-gather what they need.
    x = shard_ctx.constrain(x, ("dp", "tp", None))
    h = rms_norm(x, p_l["ln1"], cfg.norm_eps)
    if cfg.mla:
        a, kv = attn.mla_attend_train(cfg, p_l["attn"], h, positions)
    else:
        a, kv = attn.gqa_attend(cfg, p_l["attn"], h, positions, causal=True)
    x = x + a
    h = rms_norm(x, p_l["ln2"], cfg.norm_eps)
    f, aux = _ffn(cfg, p_l, h)
    return x + f, aux, kv


def block_decode(cfg: ModelConfig, p_l, x, positions, cache_l, cache_len):
    h = rms_norm(x, p_l["ln1"], cfg.norm_eps)
    if cfg.mla:
        a, new_cache = attn.mla_attend_decode(cfg, p_l["attn"], h, positions, cache_l, cache_len)
    else:
        a, new_cache = attn.gqa_attend(
            cfg, p_l["attn"], h, positions, cache=cache_l, cache_len=cache_len
        )
    x = x + a
    h = rms_norm(x, p_l["ln2"], cfg.norm_eps)
    f, aux = _ffn(cfg, p_l, h)
    return x + f, aux, new_cache


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------
def _maybe_remat(cfg: ModelConfig, fn):
    if cfg.remat:
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return fn


def embed_tokens(cfg: ModelConfig, params, tokens, embeds=None):
    x = params["embed"][tokens]
    if embeds is not None:
        # early-fusion stub: precomputed modality embeddings are prepended
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    return x


def forward(cfg: ModelConfig, params, tokens, *, embeds=None, collect_cache=False):
    """Full causal forward.  Returns (hidden, aux, caches|None)."""
    x = embed_tokens(cfg, params, tokens, embeds)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    body = _maybe_remat(
        cfg, lambda xx, pl: block_train(cfg, pl, xx, positions)
    )

    interleaved = cfg.moe and cfg.moe_every > 1
    if interleaved:
        me = cfg.moe_every
        n_super = cfg.n_layers // me
        dense = jax.tree.map(
            lambda a: a.reshape((n_super, me - 1) + a.shape[1:]),
            params["dense_blocks"],
        )

        def scan_fn(carry, inp):
            xx, aux = carry
            moe_p, dense_p = inp
            kvs = []
            for i in range(me - 1):
                p_l = jax.tree.map(lambda a: a[i], dense_p)
                xx, a, kv = body(xx, p_l)
                aux = aux + a
                kvs.append(kv)
            xx, a, kv = body(xx, moe_p)
            aux = aux + a
            kvs.append(kv)
            out = jax.tree.map(lambda *t: jnp.stack(t), *kvs) if collect_cache else 0
            return (xx, aux), out

        (x, aux), caches = jax.lax.scan(scan_fn, (x, 0.0), (params["blocks"], dense))
        if collect_cache:
            # (n_super, me, B, ...) -> (L, B, ...)
            caches = jax.tree.map(
                lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), caches
            )
    elif cfg.scan_layers:
        def scan_fn(carry, p_l):
            xx, aux = carry
            xx, a, kv = body(xx, p_l)
            return (xx, aux + a), (kv if collect_cache else 0)

        (x, aux), caches = jax.lax.scan(scan_fn, (x, 0.0), params["blocks"])
    else:
        aux = 0.0
        caches = []
        for i in range(cfg.n_layers):
            p_l = jax.tree.map(lambda a: a[i], params["blocks"])
            x, a, kv = body(x, p_l)
            aux = aux + a
            caches.append(kv)
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *caches) if collect_cache else None

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return x, aux, (caches if collect_cache else None)


def unembed(cfg: ModelConfig, params, h):
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return jnp.einsum("bsd,dv->bsv", h, w)


def lm_loss(cfg: ModelConfig, params, hidden, labels, mask):
    """Chunked cross-entropy: logits exist only one sequence-chunk at a time."""
    B, S, d = hidden.shape
    C = min(cfg.logits_chunk, S)
    n = (S + C - 1) // C
    pad = n * C - S
    h = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0))).reshape(B, n, C, d)
    y = jnp.pad(labels, ((0, 0), (0, pad))).reshape(B, n, C)
    m = jnp.pad(mask, ((0, 0), (0, pad))).reshape(B, n, C)
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def step(tot, inp):
        # checkpointed: the (B, C, V) logits chunk is recomputed in the
        # backward pass instead of being saved 16+ times (vocab 256k).
        hc, yc, mc = inp                      # (B, C, d), (B, C), (B, C)
        logits = jnp.einsum("bcd,dv->bcv", hc, w).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return tot + jnp.sum((lse - gold) * mc), None

    total, _ = jax.lax.scan(
        step, jnp.float32(0.0),
        (jnp.moveaxis(h, 1, 0), jnp.moveaxis(y, 1, 0), jnp.moveaxis(m, 1, 0)),
    )
    return total / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(cfg: ModelConfig, params, batch):
    """Scalar training loss (LM CE + MoE aux)."""
    hidden, aux, _ = forward(
        cfg, params, batch["tokens"], embeds=batch.get("embeds")
    )
    if "embeds" in batch and batch["embeds"] is not None:
        hidden = hidden[:, batch["embeds"].shape[1] :]
    ce = lm_loss(cfg, params, hidden, batch["labels"], batch["mask"])
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------
class DecodeState(NamedTuple):
    cache: Any            # per-layer stacked KV (or MLA latent) cache
    cache_len: jnp.ndarray  # (B,)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    L = cfg.n_layers
    if cfg.mla:
        c = jnp.zeros((L, batch, max_len, cfg.kv_lora_rank), dtype)
        r = jnp.zeros((L, batch, max_len, cfg.rope_head_dim), dtype)
        cache = (c, r)
    else:
        kv_shape = (L, batch, max_len, cfg.n_kv_heads, cfg.hd)
        cache = (jnp.zeros(kv_shape, dtype), jnp.zeros(kv_shape, dtype))
    return DecodeState(cache, jnp.zeros((batch,), jnp.int32))


def prefill(cfg: ModelConfig, params, tokens, *, embeds=None):
    """Forward over the prompt; returns hidden of last position + caches."""
    hidden, _, caches = forward(cfg, params, tokens, embeds=embeds, collect_cache=True)
    return hidden, caches


def decode_step(cfg: ModelConfig, params, state: DecodeState, tokens):
    """One decode step for the whole batch: tokens (B, 1) -> logits (B, V)."""
    x = embed_tokens(cfg, params, tokens)
    B = x.shape[0]
    positions = state.cache_len[:, None]

    def scan_fn(carry, inp):
        xx = carry
        p_l, cache_l = inp
        xx, _, new_cache = block_decode(cfg, p_l, xx, positions, cache_l, state.cache_len)
        return xx, new_cache

    interleaved = cfg.moe and cfg.moe_every > 1
    if interleaved:
        me = cfg.moe_every
        n_super = cfg.n_layers // me
        dense = jax.tree.map(
            lambda a: a.reshape((n_super, me - 1) + a.shape[1:]),
            params["dense_blocks"],
        )
        cache_g = jax.tree.map(
            lambda a: a.reshape((n_super, me) + a.shape[1:]), state.cache
        )

        def super_fn(xx, inp):
            moe_p, dense_p, cache_sl = inp
            new_caches = []
            for i in range(me - 1):
                p_l = jax.tree.map(lambda a: a[i], dense_p)
                c_l = jax.tree.map(lambda a: a[i], cache_sl)
                xx, _, nc = block_decode(cfg, p_l, xx, positions, c_l, state.cache_len)
                new_caches.append(nc)
            c_l = jax.tree.map(lambda a: a[me - 1], cache_sl)
            xx, _, nc = block_decode(cfg, moe_p, xx, positions, c_l, state.cache_len)
            new_caches.append(nc)
            return xx, jax.tree.map(lambda *t: jnp.stack(t), *new_caches)

        x, new_cache = jax.lax.scan(super_fn, x, (params["blocks"], dense, cache_g))
        new_cache = jax.tree.map(
            lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), new_cache
        )
    elif cfg.scan_layers:
        x, new_cache = jax.lax.scan(scan_fn, x, (params["blocks"], state.cache))
    else:
        caches = []
        for i in range(cfg.n_layers):
            p_l = jax.tree.map(lambda a: a[i], params["blocks"])
            cache_l = jax.tree.map(lambda a: a[i], state.cache)
            x, _, nc = block_decode(cfg, p_l, x, positions, cache_l, state.cache_len)
            caches.append(nc)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)

    h = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = unembed(cfg, params, h)[:, 0]
    return DecodeState(new_cache, state.cache_len + 1), logits
