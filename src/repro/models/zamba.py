"""Zamba2 hybrid: Mamba2 (SSD) backbone + one SHARED attention block applied
every ``attn_every`` layers (weight reuse is the Zamba signature).

Simplifications vs the released checkpoint (noted in DESIGN.md §5): a single
shared transformer block without per-invocation LoRA deltas, applied after
every ``attn_every``-th mamba layer; the shared block sees the raw residual
stream (no concat re-projection).  Structure — interleaving, weight sharing,
per-site KV caches — matches the paper's scaling rationale.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import shard_ctx
from repro.models import ssm
from repro.models.common import ModelConfig, rms_norm, swiglu
from repro.models.transformer import lm_loss, unembed


def _d_inner(cfg: ModelConfig) -> int:
    return 2 * cfg.d_model


def n_attn_sites(cfg: ModelConfig) -> int:
    return max(cfg.n_layers // cfg.attn_every, 1)


def build_params(cfg: ModelConfig, b):
    di = _d_inner(cfg)
    shared_cfg = cfg
    shared = {
        "ln1": b((cfg.d_model,), ("embed",), init="ones"),
        "attn": attn.build_gqa_params(shared_cfg, b, prefix_layers=False),
        "ln2": b((cfg.d_model,), ("embed",), init="ones"),
        "mlp": {
            "w_gate": b((cfg.d_model, cfg.d_ff), ("embed", "mlp")),
            "w_up": b((cfg.d_model, cfg.d_ff), ("embed", "mlp")),
            "w_down": b((cfg.d_ff, cfg.d_model), ("mlp", "embed")),
        },
    }
    return {
        "embed": b((cfg.vocab, cfg.d_model), ("vocab", "embed"), scale=0.02),
        "mamba": ssm.build_mamba2_params(cfg, b, di),
        "shared_attn": shared,
        "ln_f": b((cfg.d_model,), ("embed",), init="ones"),
        "unembed": b((cfg.d_model, cfg.vocab), ("embed", "vocab")),
    }


def _shared_block(cfg, p, x, positions, cache=None, cache_len=None):
    if cache is None:
        x = shard_ctx.constrain(x, ("dp", "tp", None))
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cache is None:
        a, kv = attn.gqa_attend(cfg, p["attn"], h, positions, causal=True)
    else:
        a, kv = attn.gqa_attend(
            cfg, p["attn"], h, positions, cache=cache, cache_len=cache_len
        )
    x = x + a
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"]), kv


def _maybe_remat(cfg, fn):
    if cfg.remat:
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return fn


def forward(cfg: ModelConfig, params, tokens, *, collect_cache=False):
    """Training/prefill forward.  Returns (hidden, aux, attn_kv_caches)."""
    di = _d_inner(cfg)
    x = params["embed"][tokens]
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    sites = n_attn_sites(cfg)
    per = cfg.n_layers // sites

    mamba_body = _maybe_remat(
        cfg, lambda xx, p_l: ssm.mamba2_block(cfg, p_l, xx, di)[0]
    )
    shared_body = _maybe_remat(
        cfg, lambda xx: _shared_block(cfg, params["shared_attn"], xx, positions)
    )

    # group mamba layers: (sites, per, ...) and interleave the shared block
    grouped = jax.tree.map(
        lambda a: a[: sites * per].reshape((sites, per) + a.shape[1:]), params["mamba"]
    )
    kvs = []
    for g in range(sites):
        p_g = jax.tree.map(lambda a: a[g], grouped)
        x, _ = jax.lax.scan(lambda xx, pl: (mamba_body(xx, pl), 0), x, p_g)
        x, kv = shared_body(x)
        kvs.append(kv)
    # trailing mamba layers not in a full group
    rem = cfg.n_layers - sites * per
    if rem:
        p_r = jax.tree.map(lambda a: a[sites * per :], params["mamba"])
        x, _ = jax.lax.scan(lambda xx, pl: (mamba_body(xx, pl), 0), x, p_r)

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    caches = jax.tree.map(lambda *xs: jnp.stack(xs), *kvs) if collect_cache else None
    return x, 0.0, caches


def loss_fn(cfg: ModelConfig, params, batch):
    hidden, aux, _ = forward(cfg, params, batch["tokens"])
    ce = lm_loss(cfg, params, hidden, batch["labels"], batch["mask"])
    return ce + aux, {"ce": ce, "aux": aux}


class ZambaState(NamedTuple):
    ssm_state: Any        # (L, B, H, Dk, Dv) stacked mamba states
    conv_state: Any       # (L, B, 3, channels)
    attn_cache: Any       # per-site KV: (sites, B, S, KV, hd) ×2
    cache_len: jnp.ndarray


def init_state(cfg: ModelConfig, batch: int, max_len: int):
    di = _d_inner(cfg)
    H = di // 64
    N = cfg.ssm_state
    sites = n_attn_sites(cfg)
    kv_shape = (sites, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return ZambaState(
        jnp.zeros((cfg.n_layers, batch, H, N, 64), jnp.float32),
        jnp.zeros((cfg.n_layers, batch, 3, di + 2 * cfg.ssm_state), cfg.dtype),
        (jnp.zeros(kv_shape, cfg.dtype), jnp.zeros(kv_shape, cfg.dtype)),
        jnp.zeros((batch,), jnp.int32),
    )


def decode_step(cfg: ModelConfig, params, state: ZambaState, tokens):
    di = _d_inner(cfg)
    x = params["embed"][tokens]
    positions = state.cache_len[:, None]
    sites = n_attn_sites(cfg)
    per = cfg.n_layers // sites

    def mamba_scan(xx, inp):
        p_l, s_l, c_l = inp
        y, (new_s, new_c) = ssm.mamba2_block(
            cfg, p_l, xx, di, state=s_l, conv_state=c_l
        )
        return y, (new_s, new_c)

    grouped_p = jax.tree.map(
        lambda a: a[: sites * per].reshape((sites, per) + a.shape[1:]), params["mamba"]
    )
    new_ssm, new_conv, new_kv = [], [], []
    for g in range(sites):
        p_g = jax.tree.map(lambda a: a[g], grouped_p)
        s_g = state.ssm_state[g * per : (g + 1) * per]
        c_g = state.conv_state[g * per : (g + 1) * per]
        x, (ns, nc) = jax.lax.scan(mamba_scan, x, (p_g, s_g, c_g))
        new_ssm.append(ns)
        new_conv.append(nc)
        cache_g = jax.tree.map(lambda a: a[g], state.attn_cache)
        x, kv = _shared_block(
            cfg, params["shared_attn"], x, positions, cache=cache_g,
            cache_len=state.cache_len,
        )
        new_kv.append(kv)
    rem = cfg.n_layers - sites * per
    if rem:
        p_r = jax.tree.map(lambda a: a[sites * per :], params["mamba"])
        s_r = state.ssm_state[sites * per :]
        c_r = state.conv_state[sites * per :]
        x, (ns, nc) = jax.lax.scan(mamba_scan, x, (p_r, s_r, c_r))
        new_ssm.append(ns)
        new_conv.append(nc)

    h = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = unembed(cfg, params, h)[:, 0]
    new_state = ZambaState(
        jnp.concatenate(new_ssm, axis=0),
        jnp.concatenate(new_conv, axis=0),
        jax.tree.map(lambda *xs: jnp.stack(xs), *new_kv),
        state.cache_len + 1,
    )
    return new_state, logits
