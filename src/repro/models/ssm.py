"""SSM / linear-recurrence core: chunked decayed linear attention.

One chunk-parallel primitive serves both assigned recurrent families
(DESIGN.md §5):

* **RWKV6 (Finch)** — per-channel data-dependent decay ``w_t ∈ (0,1)^{dk}``,
  bonus ``u`` on the current token, strict (i < t) intra-chunk mask;
* **Mamba2 (SSD)**  — per-head scalar decay broadcast over the state dim,
  inclusive (i ≤ t) mask, no bonus.

Math (per head; ``P_t = ∏_{j≤t} w_j`` within a chunk):
``S_t = diag(P_t)(S_0 + Σ_{i≤t} (k_i/P_i) ⊗ v_i)`` so with
``q̃_t = q_t⊙P_t`` and ``k̃_i = k_i/P_i`` the intra-chunk part is a masked
matmul ``(q̃ k̃ᵀ ⊙ M) v`` — MXU-shaped, and the inter-chunk part is a scan
over chunk states.  Cumulative products run in log space with clamping.

This is the TPU-native replacement for the CUDA scan kernels those papers
ship; the sequential dimension collapses from S to S/chunk.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import shard_ctx
from repro.models.common import ModelConfig, rms_norm

_LOG_MIN = -60.0  # clamp for cumulative log-decay (exp(-60) ~ 1e-26)


def chunked_linear_attention(
    q: jnp.ndarray,        # (B, S, H, Dk)
    k: jnp.ndarray,        # (B, S, H, Dk)
    v: jnp.ndarray,        # (B, S, H, Dv)
    log_w: jnp.ndarray,    # (B, S, H, Dk) negative log-decay (log w_t)
    *,
    bonus: jnp.ndarray | None = None,   # (H, Dk) current-token bonus (RWKV6)
    inclusive: bool = True,             # True: mamba (i ≤ t); False: rwkv (i < t)
    chunk: int = 64,
    initial_state: jnp.ndarray | None = None,  # (B, H, Dk, Dv)
):
    """Returns (out (B, S, H, Dv), final_state (B, H, Dk, Dv))."""
    B, S, H, Dk = q.shape
    Dv = v.shape[-1]
    chunk = min(chunk, S)
    n = (S + chunk - 1) // chunk
    pad = n * chunk - S

    def pad_t(x):
        return jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qf = pad_t(q).astype(jnp.float32).reshape(B, n, chunk, H, Dk)
    kf = pad_t(k).astype(jnp.float32).reshape(B, n, chunk, H, Dk)
    vf = pad_t(v).astype(jnp.float32).reshape(B, n, chunk, H, Dv)
    # padded steps get decay 1 (log 0) and k=0 so they don't disturb state
    lw = jnp.pad(log_w.astype(jnp.float32), ((0, 0), (0, pad), (0, 0), (0, 0)))
    if pad:
        kill = (jnp.arange(n * chunk) >= S).reshape(n, chunk)
        kf = jnp.where(kill[None, :, :, None, None], 0.0, kf)
    lw = lw.reshape(B, n, chunk, H, Dk)

    cum = jnp.cumsum(lw, axis=2)                      # log P_t
    cum = jnp.maximum(cum, _LOG_MIN)
    p_t = jnp.exp(cum)
    inv_p = jnp.exp(-cum)
    if inclusive:
        q_eff = qf * p_t
    else:
        q_eff = qf * jnp.exp(jnp.maximum(cum - lw, _LOG_MIN))  # P_{t-1} = P_t / w_t
    k_eff = kf * inv_p

    # Intra-chunk masked attention.
    s = jnp.einsum("bnthd,bnshd->bnhts", q_eff, k_eff)         # (B,n,H,t,s)
    ti = jnp.arange(chunk)
    mask = ti[:, None] >= ti[None, :] if inclusive else ti[:, None] > ti[None, :]
    s = jnp.where(mask[None, None, None, :, :], s, 0.0)
    intra = jnp.einsum("bnhts,bnshd->bnthd", s, vf)            # (B,n,t,H,Dv)

    if bonus is not None:
        diag = jnp.einsum("bnthd,bnthd->bnth", qf, kf * bonus[None, None, None])
        intra = intra + diag[..., None] * vf

    # Inter-chunk: scan chunk states S_c.
    p_last = p_t[:, :, -1]                                     # (B,n,H,Dk)
    kv_chunk = jnp.einsum("bnshd,bnshe->bnhde", k_eff, vf)     # (B,n,H,Dk,Dv)

    def step(S0, inp):
        pl_, kvc = inp                                         # (B,H,Dk), (B,H,Dk,Dv)
        S_new = pl_[..., None] * (S0 + kvc)
        return S_new, S0

    init = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((B, H, Dk, Dv), jnp.float32)
    )
    final, S_prevs = jax.lax.scan(
        step, init, (jnp.moveaxis(p_last, 1, 0), jnp.moveaxis(kv_chunk, 1, 0))
    )
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)                      # (B,n,H,Dk,Dv)
    inter = jnp.einsum("bnthd,bnhde->bnthe", q_eff, S_prevs)
    out = (intra + inter).reshape(B, n * chunk, H, Dv)[:, :S]
    return out.astype(q.dtype), final


def linear_attention_step(
    q: jnp.ndarray,        # (B, H, Dk) one step
    k: jnp.ndarray,
    v: jnp.ndarray,        # (B, H, Dv)
    w: jnp.ndarray,        # (B, H, Dk) decay in (0,1)
    state: jnp.ndarray,    # (B, H, Dk, Dv)
    *,
    bonus: jnp.ndarray | None = None,
    inclusive: bool = True,
):
    """Single-token recurrence (decode path); mirrors the chunked math."""
    qf, kf, vf, wf = (t.astype(jnp.float32) for t in (q, k, v, w))
    st = state.astype(jnp.float32)
    kv = kf[..., :, None] * vf[..., None, :]
    if inclusive:
        new_state = wf[..., None] * st + kv
        out = jnp.einsum("bhd,bhde->bhe", qf, new_state)
    else:
        read = st + (bonus[None, ..., None] * kv if bonus is not None else 0.0)
        out = jnp.einsum("bhd,bhde->bhe", qf, read)
        new_state = wf[..., None] * st + kv
    return out.astype(q.dtype), new_state.astype(state.dtype)


# ---------------------------------------------------------------------------
# RWKV6 (Finch) blocks
# ---------------------------------------------------------------------------
def build_rwkv6_params(cfg: ModelConfig, b):
    L = (cfg.n_layers,)
    lax_ = ("layers",)
    d = cfg.d_model
    H = cfg.n_heads if cfg.n_heads else d // 64
    hd = d // H
    lora = 64
    blocks = {
        "ln1": b(L + (d,), lax_ + ("embed",), init="ones"),
        "ln2": b(L + (d,), lax_ + ("embed",), init="ones"),
        # time-mix lerp coefficients (token shift)
        "mu_r": b(L + (d,), lax_ + ("embed",), init="zeros"),
        "mu_k": b(L + (d,), lax_ + ("embed",), init="zeros"),
        "mu_v": b(L + (d,), lax_ + ("embed",), init="zeros"),
        "mu_w": b(L + (d,), lax_ + ("embed",), init="zeros"),
        "mu_g": b(L + (d,), lax_ + ("embed",), init="zeros"),
        "w_r": b(L + (d, H, hd), lax_ + ("embed", "heads", "hd")),
        "w_k": b(L + (d, H, hd), lax_ + ("embed", "heads", "hd")),
        "w_v": b(L + (d, H, hd), lax_ + ("embed", "heads", "hd")),
        "w_g": b(L + (d, d), lax_ + ("embed", "mlp")),
        "w_o": b(L + (H, hd, d), lax_ + ("heads", "hd", "embed")),
        # data-dependent decay LoRA (Finch): w_t = exp(-exp(base + lora(x)))
        "decay_base": b(L + (H, hd), lax_ + ("heads", "hd"), init="zeros"),
        "decay_lora_a": b(L + (d, lora), lax_ + ("embed", "rank")),
        "decay_lora_b": b(L + (lora, H, hd), lax_ + ("rank", "heads", "hd"), init="zeros"),
        "bonus": b(L + (H, hd), lax_ + ("heads", "hd"), init="zeros"),
        "gn": b(L + (H, hd), lax_ + ("heads", "hd"), init="ones"),
        # channel-mix FFN
        "mu_ffn_k": b(L + (d,), lax_ + ("embed",), init="zeros"),
        "w_ffn_k": b(L + (d, cfg.d_ff), lax_ + ("embed", "mlp")),
        "w_ffn_v": b(L + (cfg.d_ff, d), lax_ + ("mlp", "embed")),
        "w_ffn_r": b(L + (d, d), lax_ + ("embed", "mlp")),
    }
    return {
        "embed": b((cfg.vocab, d), ("vocab", "embed"), scale=0.02),
        "blocks": blocks,
        "ln_out": b((d,), ("embed",), init="ones"),
        "unembed": b((d, cfg.vocab), ("embed", "vocab")),
    }


def _token_shift(x: jnp.ndarray, prev: jnp.ndarray | None = None):
    """x (B,S,d) -> previous-token features (zero/carry at position 0)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def rwkv6_block(cfg: ModelConfig, p, x, *, state=None):
    """One RWKV6 layer (time-mix + channel-mix).

    ``state`` is ``(S, shift_a, shift_b)``: the wkv matrix state plus the two
    token-shift carries (time-mix and channel-mix).  Returns (y, new_state).
    """
    B, S, d = x.shape
    H = cfg.n_heads if cfg.n_heads else d // 64
    hd = d // H
    wkv_state, shift_a, shift_b = state if state is not None else (None, None, None)
    x = shard_ctx.constrain(x, ("dp", "tp", None))

    xa = rms_norm(x, p["ln1"], cfg.norm_eps)
    xs = _token_shift(xa, shift_a)
    mix = lambda mu: xa + (xs - xa) * jax.nn.sigmoid(mu)
    r = jnp.einsum("bsd,dhk->bshk", mix(p["mu_r"]), p["w_r"])
    k = jnp.einsum("bsd,dhk->bshk", mix(p["mu_k"]), p["w_k"])
    v = jnp.einsum("bsd,dhk->bshk", mix(p["mu_v"]), p["w_v"])
    g = jax.nn.silu(
        jnp.einsum("bsd,de->bse", mix(p["mu_g"]), p["w_g"]).astype(jnp.float32)
    ).astype(x.dtype)

    lora = jnp.einsum("bsd,dr->bsr", mix(p["mu_w"]), p["decay_lora_a"])
    lora = jnp.einsum("bsr,rhk->bshk", jnp.tanh(lora.astype(jnp.float32)).astype(x.dtype), p["decay_lora_b"])
    log_w = -jnp.exp(
        jnp.clip(p["decay_base"][None, None].astype(jnp.float32) + lora.astype(jnp.float32), -8.0, 4.0)
    )  # log w_t = -exp(·) < 0 ⇒ w ∈ (0,1)

    bonus = p["bonus"].astype(jnp.float32)
    o, new_wkv = chunked_linear_attention(
        r, k, v, log_w, bonus=bonus, inclusive=False, chunk=cfg.ssm_chunk,
        initial_state=wkv_state,
    )
    o32 = o.astype(jnp.float32)
    o32 = o32 * jax.lax.rsqrt(jnp.mean(o32 * o32, axis=-1, keepdims=True) + cfg.norm_eps)
    o = (o32 * p["gn"][None, None].astype(jnp.float32)).astype(x.dtype)
    o = (o.reshape(B, S, d) * g.reshape(B, S, d))
    att = jnp.einsum("bshk,hkd->bsd", o.reshape(B, S, H, hd), p["w_o"])
    x = x + att
    new_shift_a = xa[:, -1:]

    xb = rms_norm(x, p["ln2"], cfg.norm_eps)
    xbs = _token_shift(xb, shift_b)
    kf = jnp.einsum("bsd,df->bsf", xb + (xbs - xb) * jax.nn.sigmoid(p["mu_ffn_k"]), p["w_ffn_k"])
    kf = jnp.square(jax.nn.relu(kf.astype(jnp.float32))).astype(x.dtype)
    ffn = jnp.einsum("bsf,fd->bsd", kf, p["w_ffn_v"])
    rg = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xbs, p["w_ffn_r"]).astype(jnp.float32)).astype(x.dtype)
    x = x + ffn * rg
    new_shift_b = xb[:, -1:]
    return x, (new_wkv, new_shift_a, new_shift_b)


# ---------------------------------------------------------------------------
# Mamba2 (SSD) block — used by the zamba2 hybrid
# ---------------------------------------------------------------------------
def build_mamba2_params(cfg: ModelConfig, b, d_inner: int, prefix_layers=True):
    L = (cfg.n_layers,) if prefix_layers else ()
    lax_ = ("layers",) if prefix_layers else ()
    d = cfg.d_model
    N = cfg.ssm_state
    H = d_inner // 64                      # head dim 64
    return {
        "ln": b(L + (d,), lax_ + ("embed",), init="ones"),
        "w_in": b(L + (d, 2 * d_inner), lax_ + ("embed", "mlp")),
        "w_bc": b(L + (d, 2 * N), lax_ + ("embed", "state")),
        "w_dt": b(L + (d, H), lax_ + ("embed", "heads")),
        "dt_bias": b(L + (H,), lax_ + ("heads",), init="zeros"),
        "a_log": b(L + (H,), lax_ + ("heads",), init="zeros"),
        "conv_w": b(L + (4, d_inner + 2 * N), lax_ + (None, "mlp"), scale=0.5),
        "d_skip": b(L + (H,), lax_ + ("heads",), init="ones"),
        "gn": b(L + (d_inner,), lax_ + ("mlp",), init="ones"),
        "w_out": b(L + (d_inner, d), lax_ + ("mlp", "embed")),
    }


def mamba2_block(cfg: ModelConfig, p, x, d_inner: int, *, state=None, conv_state=None):
    """Mamba2/SSD block (simplified single-group).  Returns (y, (ssm, conv))."""
    B, S, d = x.shape
    N = cfg.ssm_state
    H = d_inner // 64
    P = 64

    x = shard_ctx.constrain(x, ("dp", "tp", None))
    xi = rms_norm(x, p["ln"], cfg.norm_eps)
    zu = jnp.einsum("bsd,de->bse", xi, p["w_in"])
    z, u = jnp.split(zu, 2, axis=-1)                  # gate, value (B,S,d_inner)
    bc = jnp.einsum("bsd,dn->bsn", xi, p["w_bc"])     # (B,S,2N)

    # depthwise causal conv (width 4) over concat([u, bc])
    cu = jnp.concatenate([u, bc], axis=-1)
    if conv_state is None:
        conv_in = jnp.pad(cu, ((0, 0), (3, 0), (0, 0)))
    else:
        conv_in = jnp.concatenate([conv_state.astype(cu.dtype), cu], axis=1)
    w = p["conv_w"]                                   # (4, channels)
    conv = sum(conv_in[:, i : i + S] * w[i][None, None] for i in range(4))
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    u_c, bc_c = conv[..., :d_inner], conv[..., d_inner:]
    b_in, c_in = jnp.split(bc_c, 2, axis=-1)          # (B,S,N) each
    new_conv_state = conv_in[:, S : S + 3] if conv_state is not None else cu[:, -3:]

    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", xi, p["w_dt"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32)
    )                                                  # (B,S,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))       # (H,) negative
    log_decay = dt * a[None, None]                     # (B,S,H) = log w_t

    uh = u_c.reshape(B, S, H, P).astype(jnp.float32) * dt[..., None]
    q = jnp.broadcast_to(c_in[:, :, None, :], (B, S, H, N))
    k = jnp.broadcast_to(b_in[:, :, None, :], (B, S, H, N))
    lw = jnp.broadcast_to(log_decay[..., None], (B, S, H, N))

    o, new_state = chunked_linear_attention(
        q, k, uh.astype(x.dtype), lw, inclusive=True, chunk=cfg.ssm_chunk,
        initial_state=state,
    )
    o = o.astype(jnp.float32) + p["d_skip"].astype(jnp.float32)[None, None, :, None] * u_c.reshape(B, S, H, P).astype(jnp.float32)
    o = o.reshape(B, S, d_inner)
    o = o * jax.lax.rsqrt(jnp.mean(o * o, axis=-1, keepdims=True) + cfg.norm_eps)
    o = (o * p["gn"][None, None].astype(jnp.float32)).astype(x.dtype)
    o = o * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return x + jnp.einsum("bse,ed->bsd", o, p["w_out"]), (new_state, new_conv_state)
