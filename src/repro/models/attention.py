"""Attention variants: GQA (+bias/qk-norm), MLA, flash-chunked softmax.

``flash_attention`` is the default train/prefill path: an online-softmax
scan over KV chunks (the FlashAttention recurrence in pure JAX) so the
(S × S) logits matrix never materializes — required for prefill_32k and the
memory-roofline term.  ``decode_attention`` scores one query step against a
(possibly sequence-sharded) KV cache; XLA SPMD inserts the partial-softmax
collectives when the cache's sequence axis is sharded (DESIGN.md §4).

MLA follows DeepSeek-V2/MiniCPM3: queries/keys/values are low-rank
projections of cached *latents*; the decode path uses the absorbed form
(W_uk folded into the query) so per-token cache is ``kv_lora + rope_dim``
instead of ``2·H·hd``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import shard_ctx
from repro.models.common import ModelConfig, rms_norm, rope


# ---------------------------------------------------------------------------
# Flash-style chunked attention (no S×S materialization)
# ---------------------------------------------------------------------------
def expand_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """GQA: expand (B, S, KV, hd) -> (B, S, H, hd) by head-group gather.

    An explicit gather (not a reshape of the head axis) keeps the expanded
    tensor's head axis aligned with the q heads' `model` sharding: each TP
    shard slices the kv heads its q heads need from the (replicated or
    sharded) cache instead of forcing an axis-split reshard.
    """
    KV = k.shape[2]
    g = n_heads // KV
    idx = jnp.arange(n_heads) // g
    out = jnp.take(k, idx, axis=2)
    return shard_ctx.constrain(out, (None, None, "tp", None))


def flash_attention(
    q: jnp.ndarray,          # (B, Sq, H, hd)
    k: jnp.ndarray,          # (B, Sk, KV, hd)
    v: jnp.ndarray,          # (B, Sk, KV, hd)
    *,
    causal: bool = True,
    q_offset: int = 0,       # absolute position of q[0] (prefill continuation)
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    causal_skip: bool = True,
) -> jnp.ndarray:
    """Online-softmax attention, chunked on BOTH axes (no S×S and no S×C
    full-Sq logits tensor — peak logits live at (B, H, q_chunk, kv_chunk)).

    ``causal_skip``: for aligned causal attention, iterate only the
    lower-triangular (q_chunk, kv_chunk) tile pairs — ~2× fewer attention
    FLOPs than masking the full rectangle (EXPERIMENTS.md §Perf iteration).
    The pair list is static, so it lowers to one scan over nq·(nq+1)/2 tiles
    carrying the (m, l, acc) state of ALL q chunks.
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    dv = v.shape[-1]                 # may differ from hd (MLA rope-extended k)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    kh = expand_kv(k, H).astype(jnp.float32)             # (B, Sk, H, hd)
    vh = expand_kv(v, H).astype(jnp.float32)             # (B, Sk, H, dv)

    q_chunk = min(q_chunk, Sq)
    if causal and causal_skip and q_offset == 0 and Sq == Sk:
        kv_chunk = q_chunk          # square tiles -> clean triangle skipping
    kv_chunk = min(kv_chunk, Sk)
    nq = (Sq + q_chunk - 1) // q_chunk
    nk = (Sk + kv_chunk - 1) // kv_chunk
    qpad = nq * q_chunk - Sq
    kpad = nk * kv_chunk - Sk
    qf = jnp.pad((q.astype(jnp.float32) * scale), ((0, 0), (0, qpad), (0, 0), (0, 0)))
    kf = jnp.pad(kh, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    vf = jnp.pad(vh, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    qf = jnp.moveaxis(qf.reshape(B, nq, q_chunk, H, hd), 1, 0)   # (nq,B,qc,H,hd)
    kf = jnp.moveaxis(kf.reshape(B, nk, kv_chunk, H, hd), 1, 0)
    vf = jnp.moveaxis(vf.reshape(B, nk, kv_chunk, H, dv), 1, 0)
    # pin chunk-stacked operands: batch over dp, heads over tp, the CHUNK
    # axis replicated — per-tile dynamic slicing then stays device-local
    # (a sequence-sharded chunk axis turns every tile fetch into an
    # all-to-all; measured +1.7 TB/step on qwen3-moe).
    qf = shard_ctx.constrain(qf, (None, "dp", None, "tp", None))
    kf = shard_ctx.constrain(kf, (None, "dp", None, "tp", None))
    vf = shard_ctx.constrain(vf, (None, "dp", None, "tp", None))

    def tile(qb, q_pos, m, l, acc, kb, vb, kv_pos):
        """One (q_chunk × kv_chunk) online-softmax update."""
        s = jnp.einsum("bqhd,bshd->bhqs", qb, kb)        # (B,H,qc,kc)
        mask = (kv_pos[None, :] <= q_pos[:, None]) if causal else (
            kv_pos[None, :] >= 0
        )
        mask = mask & (kv_pos[None, :] < Sk)
        s = jnp.where(mask[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(mask[None, None], jnp.exp(s - m_safe[..., None]), 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bhqs,bshd->bhqd", p, vb)
        return m_new, l_new, acc_new

    use_skip = causal and causal_skip and q_offset == 0 and Sq == Sk and nq > 1

    if use_skip:
        # static lower-triangle tile list (i >= j in chunk-grid coordinates,
        # mapping q tile i to kv tiles [0 .. i*qc/kc])
        pairs = [
            (i, j) for i in range(nq) for j in range(nk)
            if j * kv_chunk <= i * q_chunk + q_chunk - 1
        ]
        pi = jnp.asarray([p[0] for p in pairs], jnp.int32)
        pj = jnp.asarray([p[1] for p in pairs], jnp.int32)

        @functools.partial(jax.checkpoint,
                           policy=jax.checkpoint_policies.nothing_saveable)
        def pair_step(carry, inp):
            m, l, acc = carry                            # (nq,B,H,qc[,dv])
            i, j = inp
            qb = qf[i]
            kb, vb = kf[j], vf[j]
            q_pos = q_offset + i * q_chunk + jnp.arange(q_chunk)
            kv_pos = j * kv_chunk + jnp.arange(kv_chunk)
            mi, li, ai = tile(qb, q_pos, m[i], l[i], acc[i], kb, vb, kv_pos)
            return (m.at[i].set(mi), l.at[i].set(li), acc.at[i].set(ai)), None

        m0 = jnp.full((nq, B, H, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((nq, B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((nq, B, H, q_chunk, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(pair_step, (m0, l0, a0), (pi, pj))
        out = acc / jnp.maximum(l, 1e-30)[..., None]     # (nq,B,H,qc,dv)
        out = jnp.moveaxis(out, 3, 2)                    # (nq,B,qc,H,dv)
        out = jnp.moveaxis(out, 0, 1).reshape(B, nq * q_chunk, H, dv)[:, :Sq]
        return out.astype(q.dtype)

    def q_step(_, q_inp):
        qb, qi = q_inp                                   # (B,qc,H,hd), ()
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        @functools.partial(jax.checkpoint,
                           policy=jax.checkpoint_policies.nothing_saveable)
        def kv_step(carry, kv_inp):
            m, l, acc = carry
            kb, vb, ki = kv_inp                          # (B,kc,H,hd)
            kv_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            return tile(qb, q_pos, m, l, acc, kb, vb, kv_pos), None

        m0 = jnp.full((B, H, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (kf, vf, jnp.arange(nk))
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]     # (B,H,qc,dv)
        return None, jnp.moveaxis(out, 1, 2)             # (B,qc,H,dv)

    _, outs = jax.lax.scan(q_step, None, (qf, jnp.arange(nq)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * q_chunk, H, dv)[:, :Sq]
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,          # (B, 1, H, hd)
    k_cache: jnp.ndarray,    # (B, S, KV, hd)
    v_cache: jnp.ndarray,
    cache_len: jnp.ndarray,  # (B,) valid prefix length
) -> jnp.ndarray:
    B, _, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    g = H // KV
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    qf = (q.astype(jnp.float32) * scale).reshape(B, KV, g, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k_cache.astype(jnp.float32))
    pos = jnp.arange(S)
    mask = pos[None, :] < cache_len[:, None]                  # (B, S)
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block (covers dense archs; bias and qk-norm options)
# ---------------------------------------------------------------------------
def build_gqa_params(cfg: ModelConfig, b, prefix_layers: bool = True):
    L = (cfg.n_layers,) if prefix_layers else ()
    lax_ = ("layers",) if prefix_layers else ()
    hd = cfg.hd
    p = {
        "wq": b(L + (cfg.d_model, cfg.n_heads, hd), lax_ + ("embed", "heads", "hd")),
        "wk": b(L + (cfg.d_model, cfg.n_kv_heads, hd), lax_ + ("embed", "kv_heads", "hd")),
        "wv": b(L + (cfg.d_model, cfg.n_kv_heads, hd), lax_ + ("embed", "kv_heads", "hd")),
        "wo": b(L + (cfg.n_heads, hd, cfg.d_model), lax_ + ("heads", "hd", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = b(L + (cfg.n_heads, hd), lax_ + ("heads", "hd"), init="zeros")
        p["bk"] = b(L + (cfg.n_kv_heads, hd), lax_ + ("kv_heads", "hd"), init="zeros")
        p["bv"] = b(L + (cfg.n_kv_heads, hd), lax_ + ("kv_heads", "hd"), init="zeros")
    if cfg.qk_norm:
        p["q_norm"] = b(L + (hd,), lax_ + ("hd",), init="ones")
        p["k_norm"] = b(L + (hd,), lax_ + ("hd",), init="ones")
    return p


def gqa_qkv(cfg: ModelConfig, p, x, positions):
    """Project to rotary q/k and v. x (B, S, d) -> q (B,S,H,hd), k/v (B,S,KV,hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attend(cfg: ModelConfig, p, x, positions, *, causal=True, kv=None,
               cache=None, cache_len=None):
    """Full GQA block: returns (out, new_kv_for_cache).

    ``kv``: externally supplied (k, v) for cross-attention.
    ``cache``/``cache_len``: decode path — append one step, score vs cache.
    """
    if kv is None:
        q, k, v = gqa_qkv(cfg, p, x, positions)
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        if cfg.qkv_bias:
            q = q + p["bq"]
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        q = rope(q, positions, cfg.rope_theta)
        k, v = kv

    if cache is not None:
        k_cache, v_cache = cache
        k_cache = _scatter_step(k_cache, k, cache_len)
        v_cache = _scatter_step(v_cache, v, cache_len)
        out = decode_attention(q, k_cache, v_cache, cache_len + 1)
        o = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
        return o, (k_cache, v_cache)

    out = flash_attention(q, k, v, causal=causal)
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return o, (k, v)


def _scatter_step(cache: jnp.ndarray, step: jnp.ndarray, lens: jnp.ndarray):
    """Write one new (B, 1, KV, hd) step at per-row position ``lens``."""
    B, S = cache.shape[0], cache.shape[1]
    onehot = (jnp.arange(S)[None, :] == lens[:, None]).astype(cache.dtype)
    return cache * (1 - onehot[:, :, None, None]) + onehot[:, :, None, None] * step.astype(cache.dtype)


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention) — MiniCPM3 / DeepSeek-V2 style
# ---------------------------------------------------------------------------
def build_mla_params(cfg: ModelConfig, b):
    L = (cfg.n_layers,)
    lax_ = ("layers",)
    hd = cfg.hd                      # nope head dim (== v head dim)
    rd = cfg.rope_head_dim
    return {
        "w_dq": b(L + (cfg.d_model, cfg.q_lora_rank), lax_ + ("embed", "rank")),
        "q_norm": b(L + (cfg.q_lora_rank,), lax_ + ("rank",), init="ones"),
        "w_uq": b(L + (cfg.q_lora_rank, cfg.n_heads, hd + rd), lax_ + ("rank", "heads", "hd")),
        "w_dkv": b(L + (cfg.d_model, cfg.kv_lora_rank + rd), lax_ + ("embed", "rank")),
        "kv_norm": b(L + (cfg.kv_lora_rank,), lax_ + ("rank",), init="ones"),
        "w_uk": b(L + (cfg.kv_lora_rank, cfg.n_heads, hd), lax_ + ("rank", "heads", "hd")),
        "w_uv": b(L + (cfg.kv_lora_rank, cfg.n_heads, hd), lax_ + ("rank", "heads", "hd")),
        "wo": b(L + (cfg.n_heads, hd, cfg.d_model), lax_ + ("heads", "hd", "embed")),
    }


def mla_latents(cfg: ModelConfig, p, x, positions):
    """Compute the cached latent: c_kv (B,S,r) and rotary k_rope (B,S,rd)."""
    dkv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    c_kv = rms_norm(dkv[..., : cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = rope(dkv[..., None, cfg.kv_lora_rank :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_queries(cfg: ModelConfig, p, x, positions):
    hd, rd = cfg.hd, cfg.rope_head_dim
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"])
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_attend_train(cfg: ModelConfig, p, x, positions):
    """Training/prefill MLA: materialize per-head k/v from latents."""
    hd, rd = cfg.hd, cfg.rope_head_dim
    c_kv, k_rope = mla_latents(cfg, p, x, positions)
    q_nope, q_rope = mla_queries(cfg, p, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"])
    H = cfg.n_heads
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :], k_rope.shape[:2] + (H, rd))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    out = flash_attention(q_full, k_full, v, causal=True)
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return o, (c_kv, k_rope)


def mla_attend_decode(cfg: ModelConfig, p, x, positions, cache, cache_len):
    """Absorbed-form decode: score directly against the latent cache.

    q̃ = q_nope · W_uk  →  (B, 1, H, r); per-token cache is just (r + rd).
    """
    hd, rd = cfg.hd, cfg.rope_head_dim
    c_cache, r_cache = cache                     # (B, S, r), (B, S, rd)
    c_new, k_rope_new = mla_latents(cfg, p, x, positions)
    B, S = c_cache.shape[0], c_cache.shape[1]
    onehot = (jnp.arange(S)[None, :] == cache_len[:, None]).astype(c_cache.dtype)
    c_cache = c_cache * (1 - onehot[..., None]) + onehot[..., None] * c_new.astype(c_cache.dtype)
    r_cache = r_cache * (1 - onehot[..., None]) + onehot[..., None] * k_rope_new.astype(r_cache.dtype)

    q_nope, q_rope = mla_queries(cfg, p, x, positions)
    q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])     # absorbed
    scale = 1.0 / jnp.sqrt(jnp.float32(hd + rd))
    s = (
        jnp.einsum("bshr,btr->bhst", q_abs.astype(jnp.float32), c_cache.astype(jnp.float32))
        + jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32), r_cache.astype(jnp.float32))
    ) * scale
    mask = jnp.arange(S)[None, :] < (cache_len + 1)[:, None]
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    pr = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhst,btr->bshr", pr, c_cache.astype(jnp.float32))
    out = jnp.einsum("bshr,rhk->bshk", ctx, p["w_uv"].astype(jnp.float32))
    o = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])
    return o, (c_cache, r_cache)
