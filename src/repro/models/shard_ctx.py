"""Mesh-aware sharding hints for model internals.

Model code is mesh-agnostic; launchers ``activate(mesh)`` before tracing and
the helpers here resolve symbolic dims — ``"dp"`` (pod+data), ``"tp"``
(model) — into concrete PartitionSpecs, silently no-op'ing when inactive
(CPU tests).  Used for the constraints XLA cannot infer profitably on its
own: sequence-parallel residual streams between blocks (saved scan carries
shrink by the TP degree) and head-aligned attention intermediates.
"""
from __future__ import annotations

import contextlib
from typing import Iterable

import jax
from jax.sharding import PartitionSpec as P

_MESH = None  # concrete jax.sharding.Mesh when active


def activate(mesh) -> None:
    global _MESH
    _MESH = mesh


def deactivate() -> None:
    global _MESH
    _MESH = None


@contextlib.contextmanager
def use_mesh(mesh):
    activate(mesh)
    try:
        with mesh:
            yield
    finally:
        deactivate()


def active() -> bool:
    return _MESH is not None


def dp_size() -> int:
    """Product of the data-parallel axes (1 when inactive)."""
    if _MESH is None:
        return 1
    import math

    return math.prod(
        s for a, s in dict(_MESH.shape).items() if a in ("pod", "data")
    )


def tp_size() -> int:
    if _MESH is None:
        return 1
    return dict(_MESH.shape).get("model", 1)


def _resolve(dim):
    """Map symbolic dim -> mesh axes (or None when axes absent)."""
    axes = set(_MESH.axis_names)
    if dim is None:
        return None
    if dim == "dp":
        use = tuple(a for a in ("pod", "data") if a in axes)
        return use if use else None
    if dim == "tp":
        return "model" if "model" in axes else None
    return dim if dim in axes else None


def constrain(x, dims: Iterable, *, divisible: bool = True):
    """with_sharding_constraint(x, NamedSharding(mesh, P(resolved dims)));
    no-op when inactive or when a dim does not divide its axes."""
    if _MESH is None:
        return x
    import math

    from jax.sharding import NamedSharding

    sizes = dict(_MESH.shape)
    resolved = []
    for i, dim in enumerate(dims):
        r = _resolve(dim)
        if r is not None and divisible:
            axes = r if isinstance(r, tuple) else (r,)
            sz = math.prod(sizes.get(a, 1) for a in axes)
            if sz and x.shape[i] % sz != 0:
                r = None
        resolved.append(r)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, P(*resolved))
    )
