"""RWKV6 (Finch) full model: attention-free LM with O(1) decode state."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import ssm
from repro.models.common import ModelConfig, rms_norm
from repro.models.transformer import lm_loss, unembed


def forward(cfg: ModelConfig, params, tokens, *, collect_state=False):
    x = params["embed"][tokens]
    body = (
        jax.checkpoint(
            lambda xx, p_l: ssm.rwkv6_block(cfg, p_l, xx)[0],
            policy=jax.checkpoint_policies.nothing_saveable,
        )
        if cfg.remat
        else (lambda xx, p_l: ssm.rwkv6_block(cfg, p_l, xx)[0])
    )
    x, _ = jax.lax.scan(lambda xx, pl: (body(xx, pl), 0), x, params["blocks"])
    return rms_norm(x, params["ln_out"], cfg.norm_eps), 0.0, None


def loss_fn(cfg: ModelConfig, params, batch):
    hidden, aux, _ = forward(cfg, params, batch["tokens"])
    ce = lm_loss(cfg, params, hidden, batch["labels"], batch["mask"])
    return ce + aux, {"ce": ce, "aux": aux}


class RwkvState(NamedTuple):
    wkv: jnp.ndarray       # (L, B, H, hd, hd) fp32
    shift_a: jnp.ndarray   # (L, B, 1, d)
    shift_b: jnp.ndarray   # (L, B, 1, d)
    cache_len: jnp.ndarray # (B,) position counter (no KV growth — O(1) state)


def init_state(cfg: ModelConfig, batch: int, max_len: int = 0):
    d = cfg.d_model
    H = cfg.n_heads if cfg.n_heads else d // 64
    hd = d // H
    L = cfg.n_layers
    return RwkvState(
        jnp.zeros((L, batch, H, hd, hd), jnp.float32),
        jnp.zeros((L, batch, 1, d), cfg.dtype),
        jnp.zeros((L, batch, 1, d), cfg.dtype),
        jnp.zeros((batch,), jnp.int32),
    )


def decode_step(cfg: ModelConfig, params, state: RwkvState, tokens):
    """One token through all layers; the recurrent state replaces any KV."""
    x = params["embed"][tokens]           # (B, 1, d)

    def scan_fn(xx, inp):
        p_l, wkv_l, sa_l, sb_l = inp
        y, (nw, nsa, nsb) = ssm.rwkv6_block(cfg, p_l, xx, state=(wkv_l, sa_l, sb_l))
        return y, (nw, nsa, nsb)

    x, (nw, nsa, nsb) = jax.lax.scan(
        scan_fn, x, (params["blocks"], state.wkv, state.shift_a, state.shift_b)
    )
    h = rms_norm(x, params["ln_out"], cfg.norm_eps)
    logits = unembed(cfg, params, h)[:, 0]
    return RwkvState(nw, nsa, nsb, state.cache_len + 1), logits
