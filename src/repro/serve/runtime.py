"""Async continuous-batching serve runtime (DESIGN.md §13).

``ServeEngine`` (engine.py) is a synchronous call-in/call-out wrapper: one
caller, one batch, one blocking device round-trip.  This module is the
serving *process* around it — the piece that lets a single engine sustain
interleaved IF/IS/RF/RS traffic with concurrent streaming updates:

* **admission** — :meth:`ServeRuntime.submit` appends a request (its own
  semantics flag, ef, k, and optional deadline) to a bounded FIFO; requests
  whose deadline already passed are rejected *at admission* with
  :class:`DeadlineExceeded` (never silently dropped), and the bound gives
  callers backpressure instead of an unbounded queue;
* **coalescing** — the dispatcher packs the longest run of compatible
  pending requests (same static ``(ef, k)`` compile key; semantics are
  runtime state, DESIGN.md §10) into one micro-batch, padded to a
  :data:`~repro.serve.engine.BATCH_BUCKETS` shape, so any traffic mix hits
  the one compiled ``search_mixed`` program per bucket;
* **dispatch overlap** — the dispatcher thread only *launches* the device
  program (jax dispatch is asynchronous) and hands the in-flight batch to a
  completion thread that blocks and resolves futures, so host-side packing
  of batch ``i+1`` overlaps device execution of batch ``i``;
* **snapshot semantics** — updates are functional: the writer builds a new
  :class:`~repro.core.store.IndexStore` and swaps the engine's index
  *reference* atomically.  Query batches pin the index once at dequeue
  time, so an in-flight batch always reads one consistent snapshot, and
  FIFO order gives the external contract: a query admitted before a write
  answers against the pre-write snapshot, one admitted after against the
  post-write snapshot — never a torn mix;
* **fleet health** — :class:`FleetServeMonitor` wires the sharded path's
  per-shard probe timings (:func:`~repro.core.sharded.make_shard_probe_fns`)
  into :class:`~repro.ft.straggler.FleetMonitor` slow-shard detection and
  :func:`~repro.ft.elastic.plan_serve_rescale` replica planning.

Every row of a fused search batch is bitwise independent of the rest of the
batch (DESIGN.md §10), which is what makes continuous batching *exact*
here: however the coalescer slices the stream, each request's answer equals
a direct ``search_mixed`` call on its pinned snapshot, bit for bit
(tests/test_serve_runtime.py pins this).
"""
from __future__ import annotations

import collections
import dataclasses
import math
import random
import threading
import time
import queue as _queue
from concurrent.futures import Future
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.ft.elastic import RescalePlan, plan_serve_rescale
from repro.ft.straggler import FleetMonitor, StragglerConfig
from repro.serve.engine import ServeEngine, bucket_batch_size


_LAT_RESERVOIR_CAP = 4096


class LatencyReservoir:
    """Fixed-size uniform sample of a latency stream (Vitter's Algorithm R).

    ``stats()`` wants percentiles over the whole run, but a long-lived
    runtime must not grow host memory with traffic.  The first ``cap``
    samples are kept verbatim; after that each new sample replaces a
    uniformly random held slot with probability ``cap / seen``, which keeps
    the held set a uniform random sample of everything ever offered.  The
    RNG is seeded so repeated runs report identical percentiles.
    """

    def __init__(self, cap: int = _LAT_RESERVOIR_CAP, *, seed: int = 0):
        if cap <= 0:
            raise ValueError(f"reservoir cap must be positive, got {cap}")
        self.cap = cap
        self.seen = 0
        self._rng = random.Random(seed)
        self._sample: list[float] = []

    def offer(self, x: float) -> None:
        self.seen += 1
        if len(self._sample) < self.cap:
            self._sample.append(x)
        else:
            j = self._rng.randrange(self.seen)
            if j < self.cap:
                self._sample[j] = x

    def extend(self, xs) -> None:
        for x in xs:
            self.offer(x)

    def __len__(self) -> int:
        return len(self._sample)

    def __iter__(self):
        return iter(self._sample)


class DeadlineExceeded(Exception):
    """A request's deadline passed before it could be dispatched.

    Raised *into the request's future* both at admission (deadline already
    in the past) and at dequeue (expired while queued) — an expired request
    is always answered with this error, never silently dropped.
    """


class QueueFull(Exception):
    """Admission bound hit: the caller must shed load or retry later."""


class ServeReply(NamedTuple):
    """One request's answer + the provenance the consistency tests pin."""

    ids: np.ndarray        # (k,) int32 global ids, -1 padded
    dist: np.ndarray       # (k,) f32 squared distances
    latency_s: float       # submit → future-resolution wall time
    index: Any             # the pinned UGIndex snapshot this answered against


@dataclasses.dataclass
class RuntimeConfig:
    max_batch: int = 256     # coalescer cap (one micro-batch's request count)
    max_queue: int = 4096    # admission bound (pending requests + writes)
    max_inflight: int = 2    # dispatched-but-unresolved micro-batches
    default_ef: int = 64
    default_k: int = 10


@dataclasses.dataclass
class _Query:
    q_v: jnp.ndarray         # (d,)
    q_int: jnp.ndarray       # (2,)
    flag: int                # FLAG_IF | FLAG_IS (runtime semantics)
    ef: int
    k: int
    deadline: float | None   # absolute clock() time, None = no deadline
    future: Future
    t_submit: float


@dataclasses.dataclass
class _Write:
    kind: str                # "upsert" | "remove"
    payload: tuple
    future: Future
    t_submit: float


class ServeRuntime:
    """Continuous-batching loop over a :class:`ServeEngine`.

    Two execution modes share all of the machinery:

    * **threaded** — :meth:`start` spawns the dispatcher + completer pair;
      :meth:`stop` drains and joins them.  This is the serving mode
      (``launch/serve.py --async``, ``bench_serve``).
    * **inline** — :meth:`run_until_idle` pumps the same dequeue → coalesce
      → dispatch → complete pipeline on the caller's thread until the queue
      is empty.  Deterministic, thread-free; what most unit tests drive.

    The engine's ``search_backend``/``search_width`` are honored; writes go
    through ``ServeEngine.upsert``/``remove`` and therefore reuse the
    single-sync :func:`~repro.serve.engine.upsert_chunk_plan` and the
    bucketed update programs (DESIGN.md §11).
    """

    def __init__(
        self,
        engine: ServeEngine,
        config: RuntimeConfig = RuntimeConfig(),
        *,
        clock=time.monotonic,
    ):
        if engine.index is None:
            raise ValueError("engine has no index attached")
        self.engine = engine
        self.cfg = config
        self.clock = clock
        self._cv = threading.Condition()
        self._pending: collections.deque = collections.deque()
        self._inflight: _queue.Queue = _queue.Queue(maxsize=config.max_inflight)
        self._dispatcher: threading.Thread | None = None
        self._completer: threading.Thread | None = None
        self._stopping = False
        self._stats_lock = threading.Lock()
        self._latencies = LatencyReservoir()
        self._completed = 0
        self._rejected = 0
        self._writes = 0
        # Wall clock for qps accounts *active* serving windows only: time
        # between start()/stop() pairs plus time spent inside
        # run_until_idle().  Anchoring at construction (the old behaviour)
        # charged queries for index-build / idle time and made stop/start
        # cycles report qps against the wrong window.
        self._t_start: float | None = None
        self._wall_accum = 0.0

    # ------------------------------------------------------------ admission
    def submit(
        self,
        q_v,
        q_int,
        sem,
        *,
        ef: int | None = None,
        k: int | None = None,
        deadline: float | None = None,
    ) -> Future:
        """Admit one query; returns a future resolving to a :class:`ServeReply`.

        ``sem`` is a :class:`~repro.core.Semantics` or a raw flag int;
        ``deadline`` is an absolute ``clock()`` time.  An already-expired
        request is rejected immediately (future carries
        :class:`DeadlineExceeded`); a full queue raises :class:`QueueFull`
        synchronously so the caller sees backpressure.
        """
        from repro.core import as_sem_flags

        fut: Future = Future()
        now = self.clock()
        flag = int(np.asarray(as_sem_flags([sem], 1))[0])
        if deadline is not None and deadline <= now:
            self._reject(fut, DeadlineExceeded(
                f"deadline {deadline:.3f} already passed at admission "
                f"({now:.3f})"))
            return fut
        req = _Query(
            jnp.asarray(q_v), jnp.asarray(q_int), flag,
            int(ef if ef is not None else self.cfg.default_ef),
            int(k if k is not None else self.cfg.default_k),
            deadline, fut, now,
        )
        self._enqueue(req)
        return fut

    def submit_upsert(self, x, intervals) -> Future:
        """Admit a streaming insert; future resolves to the inserted count.
        FIFO position defines its snapshot boundary: queries admitted before
        it answer pre-write, queries admitted after answer post-write."""
        fut: Future = Future()
        self._enqueue(_Write(
            "upsert", (jnp.atleast_2d(jnp.asarray(x)),
                       jnp.atleast_2d(jnp.asarray(intervals))),
            fut, self.clock(),
        ))
        return fut

    def submit_remove(self, ids, *, repair: bool = True) -> Future:
        """Admit a streaming delete; future resolves to the removed count."""
        fut: Future = Future()
        self._enqueue(_Write("remove", (jnp.asarray(ids), repair),
                             fut, self.clock()))
        return fut

    def _enqueue(self, item) -> None:
        with self._cv:
            if self._stopping:
                raise RuntimeError("runtime is stopping; admission closed")
            if len(self._pending) >= self.cfg.max_queue:
                raise QueueFull(
                    f"admission queue at bound {self.cfg.max_queue}")
            self._pending.append(item)
            self._cv.notify()

    def _reject(self, fut: Future, exc: Exception) -> None:
        with self._stats_lock:
            self._rejected += 1
        fut.set_exception(exc)

    # ----------------------------------------------------------- coalescing
    def _next_work(self, block: bool):
        """Dequeue the next unit of work in FIFO order: either one write op
        or the longest head run of queries sharing a compile key, capped at
        ``max_batch``.  Returns None when idle (inline mode) or stopped."""
        with self._cv:
            while True:
                if self._pending:
                    break
                if not block or self._stopping:
                    return None
                self._cv.wait()
            head = self._pending[0]
            if isinstance(head, _Write):
                self._pending.popleft()
                return head
            key = (head.ef, head.k)
            batch = []
            while (
                self._pending
                and isinstance(self._pending[0], _Query)
                and (self._pending[0].ef, self._pending[0].k) == key
                and len(batch) < self.cfg.max_batch
            ):
                batch.append(self._pending.popleft())
            return batch

    def _launch(self, batch: list[_Query]):
        """Expire dead requests, pin the snapshot, pack + pad the micro-batch
        and *launch* the device program (no blocking here — jax dispatch is
        asynchronous; the completer owns the block)."""
        from repro.core import FLAG_IF
        from repro.core.search import search_mixed

        now = self.clock()
        live = []
        for r in batch:
            if r.deadline is not None and r.deadline <= now:
                self._reject(r.future, DeadlineExceeded(
                    f"deadline expired in queue ({now - r.t_submit:.3f}s "
                    f"after admission)"))
            else:
                live.append(r)
        if not live:
            return None
        index = self.engine.index           # pin the snapshot at dequeue time
        ef, k = live[0].ef, live[0].k
        B = len(live)
        qv = jnp.stack([r.q_v for r in live])
        qint = jnp.stack([r.q_int for r in live])
        flags = jnp.asarray([r.flag for r in live], jnp.int32)
        Bp = bucket_batch_size(B)
        if Bp != B:
            pad = Bp - B
            qv = jnp.concatenate([qv, jnp.zeros((pad, qv.shape[1]), qv.dtype)])
            dead = jnp.broadcast_to(
                jnp.asarray([2.0, -2.0], qint.dtype), (pad, 2))
            qint = jnp.concatenate([qint, dead])
            flags = jnp.concatenate(
                [flags, jnp.full((pad,), FLAG_IF, jnp.int32)])
        res = search_mixed(
            index.store, qv, qint, flags, ef=ef, k=k,
            backend=self.engine.search_backend, width=self.engine.search_width,
        )
        return live, res, index

    def _complete(self, inflight) -> None:
        """Block on one in-flight micro-batch and resolve its futures."""
        live, res, index = inflight
        ids = np.asarray(res.ids)           # blocks until the batch is done
        dist = np.asarray(res.dist)
        now = self.clock()
        lats = []
        for i, r in enumerate(live):
            lat = now - r.t_submit
            lats.append(lat)
            r.future.set_result(ServeReply(ids[i], dist[i], lat, index))
        with self._stats_lock:
            self._completed += len(live)
            self._latencies.extend(lats)

    def _apply_write(self, w: _Write) -> None:
        """Run one write through the engine.  ``ServeEngine.upsert/remove``
        build the new index functionally and swap ``engine.index`` — an
        atomic reference store, so concurrent dequeues see either the old
        or the new snapshot, never a mix."""
        try:
            if w.kind == "upsert":
                x, ivs = w.payload
                out = self.engine.upsert(None, ivs, x=x)
            else:
                ids, repair = w.payload
                out = self.engine.remove(ids, repair=repair)
            with self._stats_lock:
                self._writes += 1
            w.future.set_result(out)
        except Exception as e:  # noqa: BLE001 — surface to the submitter
            w.future.set_exception(e)

    # ------------------------------------------------------------ execution
    def run_until_idle(self) -> int:
        """Inline mode: pump dequeue → dispatch → complete until the queue is
        empty.  Returns the number of work units processed.  The pump's own
        wall time counts toward the qps window (stats())."""
        done = 0
        t0 = self.clock()
        try:
            while True:
                work = self._next_work(block=False)
                if work is None:
                    return done
                done += 1
                if isinstance(work, _Write):
                    self._apply_write(work)
                else:
                    inflight = self._launch(work)
                    if inflight is not None:
                        self._complete(inflight)
        finally:
            with self._stats_lock:
                self._wall_accum += self.clock() - t0

    def _dispatch_loop(self) -> None:
        while True:
            work = self._next_work(block=True)
            if work is None:
                break
            if isinstance(work, _Write):
                self._apply_write(work)
            else:
                inflight = self._launch(work)
                if inflight is not None:
                    self._inflight.put(inflight)   # backpressure at cap
        self._inflight.put(None)                   # completer shutdown

    def _complete_loop(self) -> None:
        while True:
            inflight = self._inflight.get()
            if inflight is None:
                break
            self._complete(inflight)

    def start(self) -> "ServeRuntime":
        if self._dispatcher is not None:
            raise RuntimeError("runtime already started")
        with self._stats_lock:
            self._t_start = self.clock()
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="serve-dispatch", daemon=True)
        self._completer = threading.Thread(
            target=self._complete_loop, name="serve-complete", daemon=True)
        self._dispatcher.start()
        self._completer.start()
        return self

    def stop(self) -> None:
        """Drain the queue, then join both threads."""
        with self._cv:
            self._stopping = True
            self._cv.notify_all()
        if self._dispatcher is not None:
            self._dispatcher.join()
            self._completer.join()
            self._dispatcher = self._completer = None
        with self._stats_lock:
            if self._t_start is not None:
                self._wall_accum += self.clock() - self._t_start
                self._t_start = None
        # _stopping only closes admission once threads exist; inline-mode
        # users never set it, so a stopped runtime can be started again.
        self._stopping = False

    def __enter__(self) -> "ServeRuntime":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ---------------------------------------------------------------- stats
    def stats(self) -> dict:
        """Serving counters + latency percentiles over the current run.

        ``qps`` is completed requests over the *active* wall time — closed
        start/stop windows plus run_until_idle() pumps plus the currently
        open start() window, if any.  Percentiles come from a bounded
        uniform reservoir of the per-request latencies."""
        with self._stats_lock:
            lats = sorted(self._latencies)
            completed = self._completed
            rejected = self._rejected
            writes = self._writes
            wall = self._wall_accum
            if self._t_start is not None:
                wall += self.clock() - self._t_start
        return {
            "completed": completed,
            "rejected": rejected,
            "writes": writes,
            "qps": completed / max(wall, 1e-9),
            "p50_ms": 1e3 * _pctl(lats, 0.50),
            "p99_ms": 1e3 * _pctl(lats, 0.99),
        }


def _pctl(sorted_xs: Sequence[float], q: float) -> float:
    """Nearest-rank percentile: the smallest element with at least ``q`` of
    the sample at or below it, i.e. index ``ceil(q*n) - 1``.  (``int(q*n)``
    sits one rank high: it maps the median of [1, 2] to 2.)"""
    if not sorted_xs:
        return 0.0
    n = len(sorted_xs)
    i = min(max(math.ceil(q * n) - 1, 0), n - 1)
    return sorted_xs[i]


# --------------------------------------------------------------------------
# Fleet health: straggler probing + elastic replica planning (sharded path)
# --------------------------------------------------------------------------
class FleetServeMonitor:
    """Per-shard step timing → slow-shard mitigation + replica planning.

    One :class:`~repro.ft.straggler.StepTimer` slot per shard of a
    :class:`~repro.core.sharded.ShardedIndex`.  :meth:`probe` times each
    shard's local search (the callables from
    :func:`~repro.core.sharded.make_shard_probe_fns` — the same program the
    ``shard_map`` step runs per shard) and feeds the fleet monitor;
    :meth:`report` turns the timings into straggler ids, per-shard
    mitigation advice, and a :func:`~repro.ft.elastic.plan_serve_rescale`
    replica plan for the healthy capacity.
    """

    def __init__(
        self,
        n_shards: int,
        n_devices: int,
        cfg: StragglerConfig = StragglerConfig(),
    ):
        if n_devices % n_shards:
            raise ValueError(
                f"{n_devices} devices not divisible by {n_shards} shards")
        self.n_shards = n_shards
        self.n_devices = n_devices
        self.fleet = FleetMonitor(n_shards, cfg)

    def record(self, shard: int, seconds: float) -> None:
        self.fleet.record(shard, seconds)

    def probe(self, shard_fns, q_v, q_int, sem_flags) -> list[float]:
        """Time one local-search step per shard and record the fleet."""
        times = []
        for s, fn in enumerate(shard_fns):
            t0 = time.perf_counter()
            out = fn(q_v, q_int, sem_flags)
            jax.block_until_ready(out)
            dt = time.perf_counter() - t0
            times.append(dt)
            self.fleet.record(s, dt)
        return times

    def report(self) -> dict:
        """Fleet health snapshot: stragglers, mitigations, replica plans."""
        slow = self.fleet.stragglers()
        per_shard = self.n_devices // self.n_shards
        healthy = self.n_devices - len(slow) * per_shard
        plan = plan_serve_rescale(self.n_devices, self.n_shards)
        degraded: RescalePlan | None = None
        if slow and healthy >= self.n_shards:
            # treat each straggling shard's device group as lost capacity:
            # the replica plan for what remains is what the launcher would
            # rescale to while the slow group recompiles/recovers
            degraded = plan_serve_rescale(healthy, self.n_shards)
        return {
            "stragglers": slow,
            "recommendations": self.fleet.recommendations(),
            "plan": plan,
            "degraded_plan": degraded,
        }
