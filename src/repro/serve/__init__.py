"""Serving: batched decode engine + async continuous-batching runtime."""
from repro.serve.engine import ServeEngine
from repro.serve.runtime import (
    DeadlineExceeded,
    FleetServeMonitor,
    QueueFull,
    RuntimeConfig,
    ServeReply,
    ServeRuntime,
)
