"""Serving: batched decode engine + embedding extraction."""
from repro.serve.engine import ServeEngine
