"""Serving substrate: batched decode driver + embedding extraction.

``ServeEngine`` is the host-side loop: it jits ``decode_step`` once per
(batch, cache) shape, runs greedy/temperature decoding over a batch of
requests, and exposes ``embed`` — mean-pooled final hidden states — which is
what populates the paper's unified interval-aware index (the retrieval
deployment in launch/serve.py: embed → UG search under IF/IS/RF/RS).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.models import transformer as tr


@dataclasses.dataclass
class ServeEngine:
    model: Model
    params: Any

    def __post_init__(self):
        cfg = self.model.cfg
        self._decode = jax.jit(
            lambda p, s, t: self.model.decode_step(p, s, t)
        )
        self._embed = jax.jit(self._embed_impl)

    # ------------------------------------------------------------- embed
    def _embed_impl(self, params, tokens, mask):
        hidden, _, _ = self.model.forward(params, tokens)
        m = mask[..., None].astype(hidden.dtype)
        pooled = jnp.sum(hidden * m, axis=1) / jnp.maximum(jnp.sum(m, axis=1), 1.0)
        # L2-normalize: cosine <-> euclidean equivalence for the index
        n = jnp.linalg.norm(pooled.astype(jnp.float32), axis=-1, keepdims=True)
        return (pooled.astype(jnp.float32) / jnp.maximum(n, 1e-6))

    def embed(self, tokens: jnp.ndarray, mask: jnp.ndarray | None = None):
        if mask is None:
            mask = jnp.ones(tokens.shape, jnp.float32)
        return self._embed(self.params, tokens, mask)

    # ------------------------------------------------------------- decode
    def generate(
        self,
        prompts: jnp.ndarray,       # (B, S_prompt) int32
        max_new: int = 16,
        *,
        temperature: float = 0.0,
        seed: int = 0,
    ) -> jnp.ndarray:
        """Greedy (or sampled) continuation; prompt is fed token-by-token
        through the decode path (exactly the serve_step the dry-run lowers)."""
        cfg = self.model.cfg
        B, S = prompts.shape
        state = self.model.init_decode_state(self.params, B, S + max_new)
        key = jax.random.key(seed)
        # prompt phase
        last_logits = None
        for t in range(S):
            state, last_logits = self._decode(self.params, state, prompts[:, t : t + 1])
        outs = []
        cur = None
        for i in range(max_new):
            if temperature > 0:
                key, sub = jax.random.split(key)
                cur = jax.random.categorical(sub, last_logits / temperature)[:, None]
            else:
                cur = jnp.argmax(last_logits, axis=-1)[:, None].astype(jnp.int32)
            outs.append(cur)
            state, last_logits = self._decode(self.params, state, cur)
        return jnp.concatenate(outs, axis=1)
