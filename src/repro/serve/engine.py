"""Serving substrate: batched decode driver + embedding extraction.

``ServeEngine`` is the host-side loop: it jits ``decode_step`` once per
(batch, cache) shape, runs greedy/temperature decoding over a batch of
requests, and exposes ``embed`` — mean-pooled final hidden states — which is
what populates the paper's unified interval-aware index (the retrieval
deployment in launch/serve.py: embed → UG search under IF/IS/RF/RS).
``attach_index`` + ``retrieve`` close the loop: token batch in, interval-
aware top-k out, routed through the fused multi-expansion search kernel
(DESIGN.md §8) on the configured backend.  ``retrieve_mixed`` is the
production mixed-workload path: each request in the batch carries its own
IF/IS/RF/RS semantics, and the batch is padded to a shape bucket so
interleaved traffic of any composition and size reuses a small fixed set of
compiled programs — semantics are runtime state, never a compile key
(DESIGN.md §10).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, Sequence

import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.models import transformer as tr

if TYPE_CHECKING:  # avoid a hard serve -> core import at module load
    from repro.core import Semantics, UGIndex
    from repro.core.search import SearchResult

# Request-count buckets for ``retrieve_mixed``: a batch of B requests is
# padded to the smallest bucket ≥ B (beyond the table: the next multiple of
# the largest bucket), so mixed traffic compiles one program per bucket.
BATCH_BUCKETS = (8, 16, 32, 64, 128, 256, 512, 1024)


def bucket_batch_size(b: int, buckets: Sequence[int] = BATCH_BUCKETS) -> int:
    if b <= 0:
        # A zero-row batch must never reach a device program: padding it to
        # the smallest bucket would dispatch an all-no-op 8-row program.
        # Callers (`upsert`/`remove`/`retrieve_mixed`, the runtime coalescer)
        # return early on B == 0 instead.
        raise ValueError(f"batch size must be positive, got {b}")
    for s in buckets:
        if b <= s:
            return s
    top = buckets[-1]
    return ((b + top - 1) // top) * top


def upsert_chunk_plan(
    n_live: int, total: int, *, floor: int = 64,
    buckets: Sequence[int] = BATCH_BUCKETS,
) -> list[int]:
    """Chunk sizes for one streaming-insert call, from a single liveness sync.

    Nodes of one insert chunk are mutually invisible during candidate
    acquisition (candidates come from the pre-chunk live set), so chunk ``i``
    is bounded by half the live count *as of chunk i* — tracked host-side
    from the one ``n_live`` sync, never re-read from the device.  Each chunk
    is rounded **down** to a bucket size (or a multiple of the largest
    bucket) so every chunk of every call lands exactly on a
    :data:`BATCH_BUCKETS` shape: the compiled-program cache keys on the
    bucket, and a drifting live count can no longer mint fresh shapes.
    """
    if total <= 0:
        return []
    top = buckets[-1]
    sizes: list[int] = []
    live = max(int(n_live), 0)
    left = int(total)
    while left > 0:
        limit = max(live // 2, floor)
        if limit >= top:
            b = (limit // top) * top  # multiple-of-top shapes, like padding
        else:
            b = max((s for s in buckets if s <= limit), default=buckets[0])
        b = min(b, left)
        sizes.append(b)
        live += b
        left -= b
    return sizes


@dataclasses.dataclass
class ServeEngine:
    model: Model
    params: Any
    index: "UGIndex | None" = None
    search_backend: str | None = None   # None = auto (pallas on TPU, xla CPU)
    search_width: int = 4               # fused frontier width W

    def __post_init__(self):
        self._decode = jax.jit(
            lambda p, s, t: self.model.decode_step(p, s, t)
        )
        self._embed = jax.jit(self._embed_impl)

    # ---------------------------------------------------------- retrieval
    def attach_index(
        self, index: "UGIndex", *, backend: str | None = None, width: int | None = None
    ) -> None:
        """Attach a UGIndex; subsequent ``retrieve`` calls run against it.

        The engine holds the index's :class:`IndexStore` pytree **by
        reference** — attach copies nothing, and every retrieve passes the
        same device buffers to the search program (zero duplicate device
        copies; buffer identity is pinned in tests/test_store_planes.py).
        Functional updates (``upsert``/``remove``) swap the reference for
        the new store, so readers always see a consistent graph.
        """
        self.index = index
        if backend is not None:
            self.search_backend = backend
        if width is not None:
            self.search_width = width

    def retrieve(
        self,
        query_tokens: jnp.ndarray | None,  # (B, S) int32; None with q_v=
        q_int: jnp.ndarray,                # (B, 2) query validity intervals
        *,
        sem: "Semantics | None" = None,
        ef: int = 64,
        k: int = 10,
        mask: jnp.ndarray | None = None,
        q_v: jnp.ndarray | None = None,    # precomputed embeddings (skip embed)
    ) -> "SearchResult":
        """Embed the token batch (unless ``q_v`` is given) and run
        interval-aware search (Alg. 5+4)."""
        if self.index is None:
            raise ValueError("no index attached; call attach_index() first")
        from repro.core import Semantics

        qv = q_v if q_v is not None else self.embed(query_tokens, mask)
        return self.index.search(
            qv, jnp.asarray(q_int),
            sem=sem if sem is not None else Semantics.IF,
            ef=ef, k=k,
            backend=self.search_backend, width=self.search_width,
        )

    def retrieve_mixed(
        self,
        query_tokens: jnp.ndarray | None,  # (B, S) int32; None with q_v=
        q_int: jnp.ndarray,                # (B, 2) query validity intervals
        sem_flags,                         # per-request Semantics / flags
        *,
        ef: int = 64,
        k: int = 10,
        mask: jnp.ndarray | None = None,
        q_v: jnp.ndarray | None = None,    # precomputed embeddings (skip embed)
    ) -> "SearchResult":
        """Mixed-workload retrieval: one batch, per-request semantics.

        The batch is padded to the next :data:`BATCH_BUCKETS` size — pad
        rows carry an unsatisfiable IF window ``[2, -2]`` so Alg. 5
        certifies NULL and they are no-ops in the shared ``while_loop`` —
        then sliced back, so interleaved IF/IS/RF/RS traffic of any
        composition hits one compiled program per bucket and never
        recompiles on the semantics mix (DESIGN.md §10).
        """
        if self.index is None:
            raise ValueError("no index attached; call attach_index() first")
        from repro.core import FLAG_IF, as_sem_flags

        qv = q_v if q_v is not None else self.embed(query_tokens, mask)
        qv = jnp.asarray(qv)
        q_int = jnp.asarray(q_int)
        B = qv.shape[0]
        if B == 0:  # empty batch: no device dispatch (not even a no-op pad)
            from repro.core.search import SearchResult

            return SearchResult(
                jnp.zeros((0, k), jnp.int32), jnp.zeros((0, k), jnp.float32),
                jnp.zeros((0,), jnp.int32), jnp.int32(0),
            )
        flags = as_sem_flags(sem_flags, B)
        Bp = bucket_batch_size(B)
        if Bp != B:
            pad = Bp - B
            qv = jnp.concatenate([qv, jnp.zeros((pad, qv.shape[1]), qv.dtype)])
            dead = jnp.broadcast_to(
                jnp.asarray([2.0, -2.0], q_int.dtype), (pad, 2)
            )
            q_int = jnp.concatenate([q_int, dead])
            flags = jnp.concatenate([flags, jnp.full((pad,), FLAG_IF, jnp.int32)])
        res = self.index.search_mixed(
            qv, q_int, flags, ef=ef, k=k,
            backend=self.search_backend, width=self.search_width,
        )
        if Bp != B:
            res = type(res)(res.ids[:B], res.dist[:B], res.steps[:B], res.iters)
        return res

    # ----------------------------------------------------------- streaming
    def upsert(
        self,
        doc_tokens: jnp.ndarray | None,    # (B, S) int32; None with x=
        intervals: jnp.ndarray,            # (B, 2) validity intervals
        *,
        mask: jnp.ndarray | None = None,
        x: jnp.ndarray | None = None,      # precomputed embeddings (skip embed)
    ) -> jnp.ndarray:
        """Embed and insert a document batch into the attached index.

        Each chunk is padded to the next :data:`BATCH_BUCKETS` size so
        streaming traffic of any size reuses a small fixed set of compiled
        insert programs per capacity; pad rows carry ``valid=False`` and
        allocate nothing (DESIGN.md §11).  Nodes of one insert batch are
        mutually invisible during candidate acquisition (candidates come
        from the pre-insert live set), so a batch large relative to the
        live corpus is split into chunks bounded by half the live count —
        earlier chunks become candidates and offer targets for later ones.
        The whole chunk plan comes from :func:`upsert_chunk_plan` off a
        *single* liveness sync (``self.index.n`` blocks on the alive mask;
        re-reading it every chunk both serializes the pipeline and mints
        drifting chunk shapes that defeat the bucket program cache).
        Returns the inserted count (== B).  The engine's index reference is
        replaced (functional update), so readers of ``self.index`` always
        see a consistent graph.
        """
        if self.index is None:
            raise ValueError("no index attached; call attach_index() first")
        xv = x if x is not None else self.embed(doc_tokens, mask)
        xv = jnp.atleast_2d(jnp.asarray(xv))
        intervals = jnp.atleast_2d(jnp.asarray(intervals))
        B = xv.shape[0]
        if B == 0:  # empty batch: no device dispatch (not even a no-op pad)
            return 0
        s = 0
        for b in upsert_chunk_plan(self.index.n, B):  # ONE liveness sync
            xc = xv[s : s + b]
            ic = intervals[s : s + b]
            Bp = bucket_batch_size(b)
            valid = jnp.arange(Bp) < b
            if Bp != b:
                pad = Bp - b
                xc = jnp.concatenate(
                    [xc, jnp.zeros((pad, xc.shape[1]), xc.dtype)])
                dead = jnp.broadcast_to(
                    jnp.asarray([2.0, -2.0], ic.dtype), (pad, 2)
                )
                ic = jnp.concatenate([ic, dead])
            self.index = self.index.insert(
                xc, ic, valid=valid,
                search_backend=self.search_backend, width=self.search_width,
            )
            s += b
        return B

    def remove(self, ids, *, repair: bool = True) -> int:
        """Delete documents by id from the attached index (tombstone +
        iterative repair; ``repair=False`` defers the repair sweep).  The
        id batch is padded to a shape bucket with ``-1`` no-op rows."""
        if self.index is None:
            raise ValueError("no index attached; call attach_index() first")
        ids = jnp.atleast_1d(jnp.asarray(ids, jnp.int32))
        B = ids.shape[0]
        if B == 0:  # empty batch: no device dispatch (not even a no-op pad)
            return 0
        Bp = bucket_batch_size(B)
        if Bp != B:
            ids = jnp.concatenate([ids, jnp.full((Bp - B,), -1, jnp.int32)])
        self.index = self.index.delete(ids, repair=repair)
        return B

    # ------------------------------------------------------------- embed
    def _embed_impl(self, params, tokens, mask):
        hidden, _, _ = self.model.forward(params, tokens)
        m = mask[..., None].astype(hidden.dtype)
        pooled = jnp.sum(hidden * m, axis=1) / jnp.maximum(jnp.sum(m, axis=1), 1.0)
        # L2-normalize: cosine <-> euclidean equivalence for the index
        n = jnp.linalg.norm(pooled.astype(jnp.float32), axis=-1, keepdims=True)
        return (pooled.astype(jnp.float32) / jnp.maximum(n, 1e-6))

    def embed(self, tokens: jnp.ndarray, mask: jnp.ndarray | None = None):
        if mask is None:
            mask = jnp.ones(tokens.shape, jnp.float32)
        return self._embed(self.params, tokens, mask)

    # ------------------------------------------------------------- decode
    def generate(
        self,
        prompts: jnp.ndarray,       # (B, S_prompt) int32
        max_new: int = 16,
        *,
        temperature: float = 0.0,
        seed: int = 0,
    ) -> jnp.ndarray:
        """Greedy (or sampled) continuation; prompt is fed token-by-token
        through the decode path (exactly the serve_step the dry-run lowers —
        the decode caches are position-stepped, so multi-token prefill would
        need a per-family cache bridge; only the final prompt logits are
        kept)."""
        B, S = prompts.shape
        state = self.model.init_decode_state(self.params, B, S + max_new)
        key = jax.random.key(seed)
        # prompt phase
        last_logits = None
        for t in range(S):
            state, last_logits = self._decode(self.params, state, prompts[:, t : t + 1])
        outs = []
        cur = None
        for i in range(max_new):
            if temperature > 0:
                key, sub = jax.random.split(key)
                cur = jax.random.categorical(sub, last_logits / temperature)[:, None]
            else:
                cur = jnp.argmax(last_logits, axis=-1)[:, None].astype(jnp.int32)
            outs.append(cur)
            state, last_logits = self._decode(self.params, state, cur)
        return jnp.concatenate(outs, axis=1)
