"""Deterministic synthetic data pipeline (LM batches + index corpora)."""
from repro.data.synthetic import (CorpusConfig, LMDataConfig, host_slice,
                                  lm_batch, lm_batches, make_corpus, make_queries)
