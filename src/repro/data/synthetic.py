"""Deterministic synthetic data pipeline.

Everything is a pure function of (seed, step, shard) so restarts resume
byte-identically from the checkpointed cursor (DESIGN.md §4 fault tolerance):
no host-side RNG state survives between steps.

Two product lines:

* **LM batches** — token/label/mask pytrees at any (batch, seq) shape, with a
  Zipf-ish marginal so losses are non-degenerate;
* **Vector corpora** — Gaussian-mixture embeddings + interval attributes
  (the paper's uniform interval model §3.2 plus the short/long/mixed query
  workloads of Exp-3) for every index benchmark.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# LM token stream
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab: int
    batch: int            # global batch
    seq: int
    seed: int = 0


def lm_batch(cfg: LMDataConfig, step: int, *, frames_dim: int = 0, frames_len: int = 0):
    """Global LM batch for one step (deterministic in (seed, step))."""
    key = jax.random.fold_in(jax.random.key(cfg.seed), step)
    k_tok, k_fr = jax.random.split(key)
    # Zipf-ish marginal: square a uniform to skew towards low ids.
    u = jax.random.uniform(k_tok, (cfg.batch, cfg.seq + 1))
    toks = (u * u * (cfg.vocab - 1)).astype(jnp.int32)
    batch = {
        "tokens": toks[:, :-1],
        "labels": toks[:, 1:],
        "mask": jnp.ones((cfg.batch, cfg.seq), jnp.float32),
    }
    if frames_dim:
        batch["frames"] = jax.random.normal(
            k_fr, (cfg.batch, frames_len, frames_dim), jnp.float32
        )
    return batch


def lm_batches(cfg: LMDataConfig, start_step: int = 0, **kw) -> Iterator[dict]:
    step = start_step
    while True:
        yield lm_batch(cfg, step, **kw)
        step += 1


# ---------------------------------------------------------------------------
# Vector + interval corpora (paper benchmarks)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    n: int
    dim: int
    n_clusters: int = 32
    cluster_std: float = 0.35
    seed: int = 0
    interval_mode: str = "uniform"   # uniform | point (RFANN datasets)


def make_corpus(cfg: CorpusConfig):
    """Returns (x (n, d) f32, intervals (n, 2) f32 in [0, 1])."""
    key = jax.random.key(cfg.seed)
    kc, ka, ki = jax.random.split(key, 3)
    centers = jax.random.normal(kc, (cfg.n_clusters, cfg.dim))
    assign = jax.random.randint(ka, (cfg.n,), 0, cfg.n_clusters)
    noise = jax.random.normal(ki, (cfg.n, cfg.dim)) * cfg.cluster_std
    x = centers[assign] + noise

    kiv = jax.random.fold_in(key, 7)
    if cfg.interval_mode == "point":
        a = jax.random.uniform(kiv, (cfg.n, 1))
        intervals = jnp.concatenate([a, a], axis=1)
    else:
        pts = jax.random.uniform(kiv, (cfg.n, 2))
        intervals = jnp.sort(pts, axis=1)
    return x.astype(jnp.float32), intervals.astype(jnp.float32)


def make_queries(
    cfg: CorpusConfig,
    nq: int,
    *,
    workload: str = "uniform",      # uniform | short | long | mixed | point
    seed: int = 100,
):
    """Query vectors + intervals per the paper's workloads (Exp-1/Exp-3).

    short: selectivity < 5%  (narrow windows); long: > 20% (wide windows);
    mixed: half and half; point: degenerate [t, t] (RSANN).
    """
    key = jax.random.key(seed)
    kq, kw, kc2, ka2 = jax.random.split(key, 4)
    centers = jax.random.normal(kc2, (cfg.n_clusters, cfg.dim))
    assign = jax.random.randint(ka2, (nq,), 0, cfg.n_clusters)
    qv = centers[assign] + jax.random.normal(kq, (nq, cfg.dim)) * cfg.cluster_std

    c = jax.random.uniform(kw, (nq, 1))
    if workload == "point":
        qi = jnp.concatenate([c, c], axis=1)
    else:
        if workload == "short":
            half = jnp.full((nq, 1), 0.10)
        elif workload == "long":
            half = jnp.full((nq, 1), 0.35)
        elif workload == "mixed":
            half = jnp.where(jnp.arange(nq)[:, None] % 2 == 0, 0.10, 0.35)
        else:  # uniform widths
            half = jax.random.uniform(jax.random.fold_in(kw, 1), (nq, 1), minval=0.1, maxval=0.45)
        qi = jnp.concatenate([jnp.maximum(c - half, 0.0), jnp.minimum(c + half, 1.0)], axis=1)
    return qv.astype(jnp.float32), qi.astype(jnp.float32)


def host_slice(global_batch: dict, host_id: int, n_hosts: int) -> dict:
    """Per-host slice of a global batch (data-loader sharding on real pods)."""
    def sl(a):
        per = a.shape[0] // n_hosts
        return a[host_id * per : (host_id + 1) * per]

    return jax.tree.map(sl, global_batch)
