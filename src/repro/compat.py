"""Version-tolerance shims for jax APIs that moved between releases.

The repo targets current jax (`jax.shard_map`, `jax.sharding.AxisType`) but
must stay runnable on the 0.4.x CPU containers used for CI, where shard_map
still lives in ``jax.experimental`` and takes ``check_rep``.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` with fallback to ``jax.experimental.shard_map``."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as sm_old

    return sm_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_vma)


def axis_size(axis_name) -> int:
    """``jax.lax.axis_size`` with the classic ``psum(1, axis)`` fallback
    (which constant-folds to the static mesh-axis size)."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)
