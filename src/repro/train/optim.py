"""AdamW from scratch (no optax offline) with sharded, dtype-configurable
moment states.

Moments inherit the parameter's PartitionSpec, so optimizer memory scales
down with the same 2-D (fsdp × tp) sharding as the weights.  ``state_dtype``
lets the 100B+ MoE configs halve optimizer HBM (bf16 moments with fp32
update math — the error is dominated by bf16 gradient noise; see DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"         # cosine | linear | constant
    state_dtype: Any = jnp.float32   # bf16 halves optimizer HBM on big MoE


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def init(cfg: AdamWConfig, params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return AdamWState(jnp.zeros((), jnp.int32), jax.tree.map(zeros, params),
                      jax.tree.map(zeros, params))


def lr_at(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "linear":
        frac = jnp.clip((s - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 1.0 - frac
    else:  # cosine
        frac = jnp.clip((s - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * decay


def global_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def update(cfg: AdamWConfig, state: AdamWState, params, grads):
    """One AdamW step (fp32 math, states stored at ``state_dtype``)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) if cfg.grad_clip else 1.0
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(cfg.state_dtype), v32.astype(cfg.state_dtype)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
