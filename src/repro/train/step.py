"""Train-step factory: loss → grads → AdamW, with microbatch accumulation,
mesh-aware shardings, and (optional) int8-compressed data-parallel gradient
exchange via an explicit shard_map (DESIGN.md §4).

The baseline path is a plain ``jax.jit`` with NamedSharding-annotated inputs:
XLA SPMD inserts the gradient reduce-scatters/all-reduces implied by the 2-D
(fsdp × tp) parameter sharding.  The compressed path exists for cross-pod DP
traffic where 4× fewer bytes beats the quantization noise.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.api import Model
from repro.models.common import batch_spec
from repro.train import optim


def make_train_step(
    model: Model,
    opt_cfg: optim.AdamWConfig,
    mesh: Mesh | None = None,
    *,
    microbatches: int = 1,
    donate: bool = True,
):
    """Returns jitted ``(params, opt_state, batch) -> (params, opt_state, metrics)``."""

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def step_fn(params, opt_state, batch):
        if microbatches > 1:
            def split(a):
                b = a.shape[0] // microbatches
                return a.reshape((microbatches, b) + a.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                g_acc, l_acc = carry
                (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                return (jax.tree.map(jnp.add, g_acc, grads), l_acc + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(acc_fn, (zeros, 0.0), micro)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

        new_params, new_opt, stats = optim.update(opt_cfg, opt_state, params, grads)
        out_metrics = {"loss": loss, **metrics, **stats}
        return new_params, new_opt, out_metrics

    if mesh is None:
        return jax.jit(step_fn, donate_argnums=(0, 1) if donate else ())

    pshard = model.shardings(mesh)
    # moments inherit the parameter shardings (prefix-tree semantics)
    opt_shard = optim.AdamWState(NamedSharding(mesh, P()), pshard, pshard)
    bspec = NamedSharding(mesh, batch_spec(mesh))  # prefix spec: batch dim only
    rep = NamedSharding(mesh, P())
    return jax.jit(
        step_fn,
        in_shardings=(pshard, opt_shard, bspec),
        out_shardings=(pshard, opt_shard, rep),
        donate_argnums=(0, 1) if donate else (),
    )


def make_eval_step(model: Model, mesh: Mesh | None = None):
    def eval_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return {"loss": loss, **metrics}

    if mesh is None:
        return jax.jit(eval_fn)
    pshard = model.shardings(mesh)
    bspec = NamedSharding(mesh, batch_spec(mesh))
    rep = NamedSharding(mesh, P())
    return jax.jit(eval_fn, in_shardings=(pshard, bspec), out_shardings=rep)
