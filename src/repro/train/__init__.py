"""Training substrate: AdamW, schedules, train-step factory."""
from repro.train.optim import AdamWConfig, AdamWState, init, lr_at, update
from repro.train.step import make_eval_step, make_train_step
