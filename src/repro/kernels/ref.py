"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the semantics the kernels must reproduce bit-for-bit (up to fp32
accumulation order); kernel tests sweep shapes/dtypes and assert_allclose
against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_sq_dist(q: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """(nq, d) × (nx, d) -> (nq, nx) squared L2, fp32 accumulation."""
    q32 = q.astype(jnp.float32)
    x32 = x.astype(jnp.float32)
    qn = jnp.sum(q32 * q32, axis=-1)
    xn = jnp.sum(x32 * x32, axis=-1)
    ip = q32 @ x32.T
    return jnp.maximum(qn[:, None] + xn[None, :] - 2.0 * ip, 0.0)


def filtered_topk(
    q: jnp.ndarray,          # (nq, d)
    x: jnp.ndarray,          # (nx, d)
    obj_int: jnp.ndarray,    # (nx, 2)
    q_int: jnp.ndarray,      # (nq, 2)
    *,
    is_filter: bool,         # True: IF/RF (obj ⊆ query); False: IS/RS
    k: int,
):
    """Fused predicate-masked exact top-k (the pre-filter scan semantics)."""
    d = pairwise_sq_dist(q, x)
    if is_filter:
        ok = (obj_int[None, :, 0] >= q_int[:, None, 0]) & (
            obj_int[None, :, 1] <= q_int[:, None, 1]
        )
    else:
        ok = (obj_int[None, :, 0] <= q_int[:, None, 0]) & (
            obj_int[None, :, 1] >= q_int[:, None, 1]
        )
    d = jnp.where(ok, d, jnp.inf)
    neg, idx = jax.lax.top_k(-d, k)
    vals = -neg
    idx = jnp.where(jnp.isfinite(vals), idx, -1)
    return vals, idx.astype(jnp.int32)


def beam_merge(beam_d, beam_p, cand_d, cand_p):
    """Sorted-beam partial merge oracle: keep the ``E`` smallest of the
    beam ∪ candidate union under the total order ``(dist, payload)``.

    ``lexsort`` with the payload as tie-break realizes the exact total order
    of the bitonic network, so the oracle is bit-identical to both kernel
    backends (not merely set-equal).
    """
    E = beam_d.shape[-1]
    d = jnp.concatenate([beam_d, cand_d], axis=-1)
    p = jnp.concatenate([beam_p, cand_p], axis=-1)
    order = jnp.lexsort((p, d), axis=-1)[..., :E]
    return (
        jnp.take_along_axis(d, order, axis=-1),
        jnp.take_along_axis(p, order, axis=-1),
    )


def gather_sq_dist(x: jnp.ndarray, idx: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Beam-expansion scoring: x (n, d), idx (B, M), q (B, d) -> (B, M).

    Negative indices are padding; their distance is +inf.
    """
    n = x.shape[0]
    rows = x[jnp.clip(idx, 0, n - 1)].astype(jnp.float32)  # (B, M, d)
    diff = rows - q[:, None, :].astype(jnp.float32)
    d = jnp.sum(diff * diff, axis=-1)
    return jnp.where(idx >= 0, d, jnp.inf)
