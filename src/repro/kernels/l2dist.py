"""Tiled pairwise squared-L2 Pallas TPU kernel.

The distance computation is the compute hot-spot of every stage of the paper
(candidate generation, pruning, search scoring, ground truth); on TPU it is a
matmul in disguise — ``‖q−x‖² = ‖q‖² + ‖x‖² − 2·qᵀx`` — so the kernel is
MXU-shaped: grid ``(nq/bq, nx/bn, d/bk)`` with the contraction axis innermost
and a fp32 VMEM accumulator carried across the ``k`` loop.  Norm partials are
folded into the same pass (no second read of q/x from HBM).

Block shapes are multiples of (8, 128) so MXU/VPU tiles are fully utilized;
the defaults (bq=256, bn=256, bk=512) keep the working set
(256·512 + 256·512 + 256·256 floats ≈ 1.3 MB) comfortably inside VMEM while
amortizing HBM reads across both operand reuses.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.util import compiler_params, pad_to


def _kernel(q_ref, x_ref, o_ref, acc_ref, *, nk: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)           # (bq, bk)
    x = x_ref[...].astype(jnp.float32)           # (bn, bk)
    ip = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                            # (bq, bn)
    qn = jnp.sum(q * q, axis=1, keepdims=True)   # (bq, 1)
    xn = jnp.sum(x * x, axis=1, keepdims=True).T # (1, bn)
    acc_ref[...] += qn + xn - 2.0 * ip

    @pl.when(kk == nk - 1)
    def _flush():
        o_ref[...] = jnp.maximum(acc_ref[...], 0.0)


@functools.partial(jax.jit, static_argnames=("bq", "bn", "bk", "interpret"))
def pairwise_sq_dist(
    q: jnp.ndarray,
    x: jnp.ndarray,
    *,
    bq: int = 256,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """(nq, d) × (nx, d) -> (nq, nx) squared L2 distances (fp32)."""
    nq, d = q.shape
    nx = x.shape[0]
    bq = min(bq, pad_to(nq, 8))
    bn = min(bn, pad_to(nx, 128))
    bk = min(bk, pad_to(d, 128))
    qp = jnp.pad(q, ((0, pad_to(nq, bq) - nq), (0, pad_to(d, bk) - d)))
    xp = jnp.pad(x, ((0, pad_to(nx, bn) - nx), (0, pad_to(d, bk) - d)))
    nk = qp.shape[1] // bk
    grid = (qp.shape[0] // bq, xp.shape[0] // bn, nk)

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], xp.shape[0]), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq, bn), jnp.float32)],
        compiler_params=compiler_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qp, xp)
    return out[:nq, :nx]
