"""Shared Pallas kernel utilities (padding, compiler params, backend probe)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pad_to(n: int, m: int) -> int:
    """Round ``n`` up to a multiple of ``m`` (at least ``m``)."""
    return max(((n + m - 1) // m) * m, m)


def pad_rows(a: jnp.ndarray, n_pad: int, fill) -> jnp.ndarray:
    """Pad the leading axis of ``a`` to ``n_pad`` rows with ``fill``."""
    n = a.shape[0]
    if n_pad == n:
        return a
    return jnp.concatenate(
        [a, jnp.full((n_pad - n,) + a.shape[1:], fill, a.dtype)], axis=0
    )


def compiler_params(dimension_semantics: tuple[str, ...]):
    """TPU Mosaic compiler params, version-tolerant across jax releases."""
    from jax.experimental.pallas import tpu as pltpu

    for name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu, name, None)
        if cls is not None:
            try:
                return cls(dimension_semantics=dimension_semantics)
            except TypeError:
                continue
    return None


def on_cpu() -> bool:
    """True when running on the CPU backend → kernels use interpret mode.

    TPU is the *target*; interpret mode executes the kernel body in Python
    for correctness validation (per-kernel tests sweep shapes/dtypes)."""
    return jax.default_backend() == "cpu"
