"""Shared Pallas kernel utilities (padding, compiler params, backend probe)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pad_to(n: int, m: int) -> int:
    """Round ``n`` up to a multiple of ``m`` (at least ``m``)."""
    return max(((n + m - 1) // m) * m, m)


def pad_rows(a: jnp.ndarray, n_pad: int, fill) -> jnp.ndarray:
    """Pad the leading axis of ``a`` to ``n_pad`` rows with ``fill``."""
    n = a.shape[0]
    if n_pad == n:
        return a
    return jnp.concatenate(
        [a, jnp.full((n_pad - n,) + a.shape[1:], fill, a.dtype)], axis=0
    )


def segment_scatter(
    seg_ids: jnp.ndarray, values: jnp.ndarray, n: int, width: int
) -> jnp.ndarray:
    """Fixed-width per-segment buffers from flat ``(segment, value)`` pairs.

    The one sort-by-segment + rank scatter every fixed-shape "inverted list"
    in this repo reduces to: Alg. 2 repair sets (``build.scatter_repairs``),
    NN-descent reverse edges (``candidates._reverse_candidates``), and the
    in-neighbor sets of the delete-repair sweep (``core/updates.py``).

    Pairs with either side negative are dropped; segment ``s`` keeps the
    first ``width`` surviving values *in scan (flat-index) order* — the
    stable segment sort breaks ties by position, so ``searchsorted`` rank
    equals scan rank.  Returns ``(n, width)`` int32, ``-1``-padded.
    """
    valid = (seg_ids >= 0) & (values >= 0)
    seg = jnp.where(valid, seg_ids, n)
    order = jnp.argsort(seg, stable=True)
    seg_s = seg[order]
    val_s = values[order]
    first = jnp.searchsorted(seg_s, seg_s, side="left")
    rank = jnp.arange(seg_s.shape[0]) - first
    ok = (seg_s < n) & (rank < width)
    out = jnp.full((n + 1, width), -1, jnp.int32)
    out = out.at[jnp.where(ok, seg_s, n), jnp.where(ok, rank, 0)].set(
        jnp.where(ok, val_s, -1), mode="drop"
    )
    return out[:n]


def compiler_params(dimension_semantics: tuple[str, ...]):
    """TPU Mosaic compiler params, version-tolerant across jax releases."""
    from jax.experimental.pallas import tpu as pltpu

    for name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu, name, None)
        if cls is not None:
            try:
                return cls(dimension_semantics=dimension_semantics)
            except TypeError:
                continue
    return None


def on_cpu() -> bool:
    """True when running on the CPU backend → kernels use interpret mode.

    TPU is the *target*; interpret mode executes the kernel body in Python
    for correctness validation (per-kernel tests sweep shapes/dtypes)."""
    return jax.default_backend() == "cpu"
