"""Fused unified-prune sweep kernel (paper Alg. 3, tile-by-tile).

Construction cost is dominated by the pruning sweep: for every node ``u`` the
candidates are scanned in ascending-distance order and candidate ``t``
survives unless an already-retained ``w < t`` witnesses it — geometrically
(``α²·δ²(t,w) < δ²(u,t)``) *and* semantically (``Φ_IF`` / ``Φ_IS``,
Def. 3.1).  The legacy implementation materializes, per node block, the full
``(B, C, C)`` pairwise-distance tensor **plus two ``(B, C, C)`` boolean Φ
witness tensors** in HBM before the scan even starts — at build shapes
(``B = 1024``, ``C ≈ 400``) that is hundreds of MB per block and the
dominant HBM traffic of the build (DESIGN.md §9).

The fused sweep never forms any ``(·, C, C)`` tensor.  Each scan step
recomputes, on the fly and only for the current candidate ``t``:

* the distance **row** ``δ²(t, ·)`` — a ``(B, C)`` tile of VPU work;
* the Φ witness **rows** ``Φ_IF(u, t, ·)`` / ``Φ_IS(u, t, ·)`` — six
  comparisons against the hull / intersection of ``(I_u, I_t)``.

Peak live memory per step drops from ``O(B·C²)`` to ``O(B·C)``; the arrays
that stay resident are exactly the kernel inputs (``O(B·C·d)``).

Backends run the *identical* network: ``pallas`` through ``pl.pallas_call``
(Mosaic on TPU, interpret mode on CPU) with the batch tiled ``bb`` rows per
grid cell, ``xla`` as the same block function traced over the full batch,
and ``legacy`` as the materialize-everything-then-scan baseline.  All three
produce **bit-identical** ``status`` / repair outputs:

* every float entering a comparison is produced by :func:`cand_row_dist`,
  an *elementwise* square-difference sum.  Unlike the matmul identity the
  legacy path used to rely on (whose Eigen/MXU reduction order — and hence
  low bits — changes with the batch shape), the elementwise form is
  bitwise invariant under row blocking, so any ``bb`` tiling agrees with
  the untiled trace;
* everything else in the scan is boolean/integer algebra (exact).

The shared preprocessing (dedup, distance sort, gathers) lives in
``core/prune.py``; this module only consumes its fixed-shape outputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import intervals as iv
from repro.kernels.util import compiler_params, pad_to


def cand_row_dist(xs: jnp.ndarray, t) -> jnp.ndarray:
    """Distance row ``δ²(c_t, c_w)`` for all ``w``: (B, C, d) → (B, C).

    Elementwise square-difference sum (VPU), *not* the matmul identity: the
    per-element reduction over ``d`` is bitwise independent of the batch
    blocking, which the cross-backend bit-identity contract requires.
    """
    x_t = jax.lax.dynamic_index_in_dim(xs, t, axis=1, keepdims=False)  # (B, d)
    diff = xs - x_t[:, None, :]
    return jnp.sum(diff * diff, axis=-1)


def _col(a: jnp.ndarray, t) -> jnp.ndarray:
    """Dynamic column ``a[:, t]`` for a traced scan index ``t``."""
    return jax.lax.dynamic_index_in_dim(a, t, axis=1, keepdims=False)


def _set_col(a: jnp.ndarray, v: jnp.ndarray, t) -> jnp.ndarray:
    """Write ``a[:, t] = v`` for a traced scan index ``t``."""
    return jax.lax.dynamic_update_slice_in_dim(a, v[:, None], t, axis=1)


def sweep_block(
    i_u: jnp.ndarray,      # (B, 2)  node intervals
    xs: jnp.ndarray,       # (B, C, d) candidate vectors (distance-sorted)
    i_c: jnp.ndarray,      # (B, C, 2) candidate intervals
    d_uc: jnp.ndarray,     # (B, C) sorted δ²(u, ·), +inf pads
    valid: jnp.ndarray,    # (B, C) live candidate mask
    overlap: jnp.ndarray,  # (B, C) I_u ∩ I_c ≠ ∅ (all-True when not unified)
    *,
    m_if: int,
    m_is: int,
    alpha: float,
    unified: bool,
):
    """The fused Alg. 3 scan over one row block; Φ rows computed per step.

    Returns ``(status int32 (B, C), rep_if, rep_is)`` with repair slots
    *local* to the candidate axis (-1 = kept / invalid).
    """
    B, C = d_uc.shape
    alpha2 = jnp.float32(alpha) ** 2
    col_idx = jax.lax.broadcasted_iota(jnp.int32, (B, C), 1)

    def body(t, state):
        act_if, act_is, cnt_if, cnt_is, rep_if, rep_is = state
        d_row = cand_row_dist(xs, t)                           # (B, C)
        if unified:
            i_t = jax.lax.dynamic_index_in_dim(i_c, t, axis=1, keepdims=False)  # (B, 2)
            hull_l = jnp.minimum(i_u[:, 0], i_t[:, 0])
            hull_r = jnp.maximum(i_u[:, 1], i_t[:, 1])
            phi_if_row = (hull_l[:, None] <= i_c[..., 0]) & (i_c[..., 1] <= hull_r[:, None])
            int_l = jnp.maximum(i_u[:, 0], i_t[:, 0])
            int_r = jnp.minimum(i_u[:, 1], i_t[:, 1])
            nonempty = int_l <= int_r
            phi_is_row = (
                nonempty[:, None]
                & (i_c[..., 0] <= int_l[:, None])
                & (i_c[..., 1] >= int_r[:, None])
            )
        else:
            phi_if_row = jnp.ones((B, C), bool)
            phi_is_row = jnp.ones((B, C), bool)

        v_ok = _col(valid, t)
        s_if = v_ok
        s_is = v_ok & _col(overlap, t)

        # Witness scan (Alg. 3 lines 9-17), vectorized over the retained prefix.
        geo = (col_idx < t) & (alpha2 * d_row < _col(d_uc, t)[:, None])
        wit_if = geo & act_if & phi_if_row
        wit_is = geo & act_is & phi_is_row
        pruned_if = jnp.any(wit_if, axis=1)
        pruned_is = jnp.any(wit_is, axis=1)
        j_if = jnp.argmax(wit_if, axis=1).astype(jnp.int32)  # first witness
        j_is = jnp.argmax(wit_is, axis=1).astype(jnp.int32)

        keep_if = s_if & ~pruned_if & (cnt_if < m_if)
        keep_is = s_is & ~pruned_is & (cnt_is < m_is)
        cnt_if = cnt_if + keep_if.astype(jnp.int32)
        cnt_is = cnt_is + keep_is.astype(jnp.int32)

        act_if = _set_col(act_if, keep_if, t)
        act_is = _set_col(act_is, keep_is, t)
        rep_if = _set_col(rep_if, jnp.where(s_if & pruned_if, j_if, -1), t)
        rep_is = _set_col(rep_is, jnp.where(s_is & pruned_is, j_is, -1), t)
        return act_if, act_is, cnt_if, cnt_is, rep_if, rep_is

    init = (
        jnp.zeros((B, C), bool),
        jnp.zeros((B, C), bool),
        jnp.zeros((B,), jnp.int32),
        jnp.zeros((B,), jnp.int32),
        jnp.full((B, C), -1, jnp.int32),
        jnp.full((B, C), -1, jnp.int32),
    )
    act_if, act_is, _, _, rep_if, rep_is = jax.lax.fori_loop(0, C, body, init)
    status = act_if.astype(jnp.int32) * iv.FLAG_IF + act_is.astype(jnp.int32) * iv.FLAG_IS
    return status, rep_if, rep_is


# ----------------------------------------------------------------------- xla
@functools.partial(jax.jit, static_argnames=("m_if", "m_is", "alpha", "unified"))
def prune_sweep_xla(i_u, xs, i_c, d_uc, valid, overlap, *, m_if, m_is, alpha, unified):
    """Reference fused backend: the identical network as plain traced jnp."""
    return sweep_block(
        i_u, xs, i_c, d_uc, valid, overlap,
        m_if=m_if, m_is=m_is, alpha=alpha, unified=unified,
    )


# -------------------------------------------------------------------- pallas
@functools.partial(
    jax.jit, static_argnames=("m_if", "m_is", "alpha", "unified", "bb", "interpret")
)
def prune_sweep(
    i_u, xs, i_c, d_uc, valid, overlap,
    *,
    m_if: int,
    m_is: int,
    alpha: float,
    unified: bool,
    bb: int = 32,
    interpret: bool = False,
):
    """Pallas backend: grid over ``bb``-row tiles, whole sweep in one kernel."""
    B, C = d_uc.shape
    d = xs.shape[-1]
    Bp = pad_to(B, bb)
    if Bp != B:
        r = Bp - B
        i_u = jnp.pad(i_u, ((0, r), (0, 0)))
        xs = jnp.pad(xs, ((0, r), (0, 0), (0, 0)))
        i_c = jnp.pad(i_c, ((0, r), (0, 0), (0, 0)))
        d_uc = jnp.pad(d_uc, ((0, r), (0, 0)), constant_values=jnp.inf)
        valid = jnp.pad(valid, ((0, r), (0, 0)))
        overlap = jnp.pad(overlap, ((0, r), (0, 0)))

    kernel = functools.partial(
        _kernel, m_if=m_if, m_is=m_is, alpha=alpha, unified=unified
    )
    # Mask operands cross the pallas_call boundary as int32 (Mosaic cannot
    # take i1 memrefs; every kernel in this repo sticks to f32/i32 operands)
    # and are compared back to bool inside the kernel — value-exact.
    valid = valid.astype(jnp.int32)
    overlap = overlap.astype(jnp.int32)
    row2 = lambda i: (i, 0)
    row3 = lambda i: (i, 0, 0)
    status, rep_if, rep_is = pl.pallas_call(
        kernel,
        grid=(Bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, 2), row2),
            pl.BlockSpec((bb, C, d), row3),
            pl.BlockSpec((bb, C, 2), row3),
            pl.BlockSpec((bb, C), row2),
            pl.BlockSpec((bb, C), row2),
            pl.BlockSpec((bb, C), row2),
        ],
        out_specs=[
            pl.BlockSpec((bb, C), row2),
            pl.BlockSpec((bb, C), row2),
            pl.BlockSpec((bb, C), row2),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, C), jnp.int32),
            jax.ShapeDtypeStruct((Bp, C), jnp.int32),
            jax.ShapeDtypeStruct((Bp, C), jnp.int32),
        ],
        compiler_params=compiler_params(("arbitrary",)),
        interpret=interpret,
    )(i_u, xs, i_c, d_uc, valid, overlap)
    return status[:B], rep_if[:B], rep_is[:B]


def _kernel(iu_ref, xs_ref, ic_ref, duc_ref, valid_ref, ov_ref,
            st_ref, rif_ref, ris_ref, *, m_if, m_is, alpha, unified):
    status, rep_if, rep_is = sweep_block(
        iu_ref[...], xs_ref[...], ic_ref[...], duc_ref[...],
        valid_ref[...] != 0, ov_ref[...] != 0,
        m_if=m_if, m_is=m_is, alpha=alpha, unified=unified,
    )
    st_ref[...] = status
    rif_ref[...] = rep_if
    ris_ref[...] = rep_is


# -------------------------------------------------------------------- legacy
def _materialize_d_cc(xs: jnp.ndarray) -> jnp.ndarray:
    """Full (B, C, C) pairwise tensor, row by row from :func:`cand_row_dist`
    so the values match the fused backends bit-for-bit."""
    B, C, _ = xs.shape

    def body(t, acc):
        return jax.lax.dynamic_update_slice_in_dim(
            acc, cand_row_dist(xs, t)[:, None, :], t, axis=1
        )

    return jax.lax.fori_loop(0, C, body, jnp.zeros((B, C, C), jnp.float32))


@functools.partial(jax.jit, static_argnames=("m_if", "m_is", "alpha", "unified"))
def prune_sweep_legacy(i_u, xs, i_c, d_uc, valid, overlap, *, m_if, m_is, alpha, unified):
    """Materialize-then-scan baseline (the pre-fusion implementation shape).

    Builds the full ``(B, C, C)`` distance tensor *and* both ``(B, C, C)``
    boolean Φ witness tensors in memory before a per-node scan consumes one
    row per step — the HBM-bound pattern ``bench_build`` quantifies.
    """
    B, C = d_uc.shape
    d_cc = _materialize_d_cc(xs)
    if unified:
        iu_b = jnp.broadcast_to(i_u[:, None, None, :], (B, C, C, 2))
        iv_b = jnp.broadcast_to(i_c[:, :, None, :], (B, C, C, 2))
        iw_b = jnp.broadcast_to(i_c[:, None, :, :], (B, C, C, 2))
        phi_if_mat = iv.phi_if(iu_b, iv_b, iw_b)
        phi_is_mat = iv.phi_is(iu_b, iv_b, iw_b)
    else:
        phi_if_mat = jnp.ones((B, C, C), bool)
        phi_is_mat = jnp.ones((B, C, C), bool)

    alpha2 = jnp.float32(alpha) ** 2
    jrange = jnp.arange(C)

    def one_node(d_cc_n, d_uc_n, valid_n, overlap_n, phi_if_n, phi_is_n):
        def body(t, state):
            act_if, act_is, cnt_if, cnt_is, rep_if, rep_is = state
            v_ok = valid_n[t]
            s_if = v_ok
            s_is = v_ok & overlap_n[t]
            geo = (jrange < t) & (alpha2 * d_cc_n[t] < d_uc_n[t])
            wit_if = geo & act_if & phi_if_n[t]
            wit_is = geo & act_is & phi_is_n[t]
            pruned_if = jnp.any(wit_if)
            pruned_is = jnp.any(wit_is)
            j_if = jnp.argmax(wit_if).astype(jnp.int32)
            j_is = jnp.argmax(wit_is).astype(jnp.int32)
            keep_if = s_if & ~pruned_if & (cnt_if < m_if)
            keep_is = s_is & ~pruned_is & (cnt_is < m_is)
            cnt_if = cnt_if + keep_if.astype(jnp.int32)
            cnt_is = cnt_is + keep_is.astype(jnp.int32)
            act_if = act_if.at[t].set(keep_if)
            act_is = act_is.at[t].set(keep_is)
            rep_if = rep_if.at[t].set(jnp.where(s_if & pruned_if, j_if, -1))
            rep_is = rep_is.at[t].set(jnp.where(s_is & pruned_is, j_is, -1))
            return act_if, act_is, cnt_if, cnt_is, rep_if, rep_is

        init = (
            jnp.zeros((C,), bool),
            jnp.zeros((C,), bool),
            jnp.int32(0),
            jnp.int32(0),
            jnp.full((C,), -1, jnp.int32),
            jnp.full((C,), -1, jnp.int32),
        )
        act_if, act_is, _, _, rep_if, rep_is = jax.lax.fori_loop(0, C, body, init)
        status = act_if.astype(jnp.int32) * iv.FLAG_IF + act_is.astype(jnp.int32) * iv.FLAG_IS
        return status, rep_if, rep_is

    return jax.vmap(one_node)(d_cc, d_uc, valid, overlap, phi_if_mat, phi_is_mat)


# ------------------------------------------------------------ memory profile
def _iter_eqn_avals(jaxpr):
    """Yield output avals of every equation, recursing into sub-jaxprs
    (scan/cond/pallas bodies)."""
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                yield aval
        for p in eqn.params.values():
            for sub in _sub_jaxprs(p):
                yield from _iter_eqn_avals(sub)


def _jaxpr_types():
    """(ClosedJaxpr, Jaxpr) across jax versions: these classes moved from
    ``jax.core`` to ``jax.extend.core`` and the old aliases were removed."""
    try:
        from jax.extend import core as jcore
        return jcore.ClosedJaxpr, jcore.Jaxpr
    except (ImportError, AttributeError):
        import jax.core as jcore
        return jcore.ClosedJaxpr, jcore.Jaxpr


def _sub_jaxprs(p):
    closed_t, jaxpr_t = _jaxpr_types()
    items = p if isinstance(p, (list, tuple)) else [p]
    for it in items:
        if isinstance(it, closed_t):
            yield it.jaxpr
        elif isinstance(it, jaxpr_t):
            yield it


def sweep_memory_profile(backend: str, B: int = 64, C: int = 96, d: int = 24,
                         *, m_if: int = 32, m_is: int = 32,
                         alpha: float = 1.0, unified: bool = True) -> dict:
    """Trace one sweep and report its intermediate-tensor profile.

    Returns ``{"peak_bytes": max single intermediate, "quadratic": whether
    any (·, C, C)-shaped tensor is materialized}`` — the acceptance check
    that the fused backends never form a Φ (or distance) matrix.
    """
    f32 = jnp.float32
    args = (
        jax.ShapeDtypeStruct((B, 2), f32),
        jax.ShapeDtypeStruct((B, C, d), f32),
        jax.ShapeDtypeStruct((B, C, 2), f32),
        jax.ShapeDtypeStruct((B, C), f32),
        jax.ShapeDtypeStruct((B, C), jnp.bool_),
        jax.ShapeDtypeStruct((B, C), jnp.bool_),
    )
    kw = dict(m_if=m_if, m_is=m_is, alpha=alpha, unified=unified)
    fn = {
        "legacy": functools.partial(prune_sweep_legacy, **kw),
        "xla": functools.partial(prune_sweep_xla, **kw),
        "pallas": functools.partial(prune_sweep, interpret=True, **kw),
    }[backend]
    closed = jax.make_jaxpr(fn)(*args)
    peak = 0
    quadratic = False
    for aval in _iter_eqn_avals(closed.jaxpr):
        size = int(aval.size) * aval.dtype.itemsize if aval.shape else aval.dtype.itemsize
        peak = max(peak, size)
        if len(aval.shape) >= 2 and aval.shape[-1] == C and aval.shape[-2] == C:
            quadratic = True
    return {"peak_bytes": peak, "quadratic": quadratic}
