"""Bitonic partial-merge Pallas TPU kernel for the fused beam search.

The hot loop of Alg. 4 must fold freshly scored neighbor candidates into the
sorted ``ef``-beam every step.  The legacy path re-sorts the whole
``(ef + M)`` concatenation with a full ``argsort`` per single-node expansion;
this kernel replaces that with the classic bitonic *partial* merge
(DESIGN.md §8):

1. bitonic-sort the ``L = W·M`` candidates ascending (``L/2·O(log²L)``
   compare-exchanges, all vectorized over the lane axis);
2. keep the best ``E`` candidates, reverse them, and take the elementwise
   minimum against the (already sorted) beam — the first stage of a bitonic
   merge of the length-``2E`` concatenation, which provably yields the ``E``
   smallest elements of the union as a bitonic sequence;
3. one bitonic merge pass (``log E`` stages) re-sorts that sequence.

Amortized over the ``W`` nodes expanded per step this is several times fewer
comparator ops than the legacy argsort (see :func:`merge_comparator_count`).

Keys are f32 distances; each key carries one packed int32 payload
(``id << 1 | expanded_bit`` in the search; opaque here).  All comparisons use
the total order ``(key, payload)`` so ties are deterministic and the Pallas
and XLA backends produce **bit-identical** outputs: both run the same network
below — ``pallas`` through ``pl.pallas_call`` (Mosaic on TPU, interpret mode
on CPU), ``xla`` as plain traced jnp.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.util import compiler_params, pad_to

PAD_PAYLOAD = -2  # (id=-1) << 1 | 0 — what empty beam/candidate slots carry


def next_pow2(v: int) -> int:
    p = 1
    while p < v:
        p *= 2
    return p


def _cmp_swap(d, p, j: int, asc):
    """One compare-exchange stage between lanes ``i`` and ``i ^ j``.

    ``asc`` is a bool (or bool array broadcastable to ``d``) giving the sort
    direction of the block each element belongs to.  Comparison is on the
    total order ``(d, p)``.
    """
    idx = jax.lax.broadcasted_iota(jnp.int32, d.shape, d.ndim - 1)
    is_lo = (idx & j) == 0
    pd = jnp.where(is_lo, jnp.roll(d, -j, axis=-1), jnp.roll(d, j, axis=-1))
    pp = jnp.where(is_lo, jnp.roll(p, -j, axis=-1), jnp.roll(p, j, axis=-1))
    le = (d < pd) | ((d == pd) & (p <= pp))   # self <= partner
    ge = (d > pd) | ((d == pd) & (p >= pp))   # self >= partner
    in_order = jnp.where(is_lo, le, ge)       # pair already ascending
    take_partner = in_order != asc
    return jnp.where(take_partner, pd, d), jnp.where(take_partner, pp, p)


def _bitonic_sort(d, p):
    """Full ascending bitonic sort along the last axis (power-of-two length)."""
    L = d.shape[-1]
    idx = jax.lax.broadcasted_iota(jnp.int32, d.shape, d.ndim - 1)
    k = 2
    while k <= L:
        asc = (idx & k) == 0
        j = k // 2
        while j >= 1:
            d, p = _cmp_swap(d, p, j, asc)
            j //= 2
        k *= 2
    return d, p


def _merge_block(beam_d, beam_p, cand_d, cand_p):
    """Merge sorted beam (..., E) with unsorted candidates (..., L): return
    the E smallest of the union, ascending in the ``(d, p)`` total order."""
    E = beam_d.shape[-1]
    L = cand_d.shape[-1]
    cand_d, cand_p = _bitonic_sort(cand_d, cand_p)
    if L >= E:
        cand_d = cand_d[..., :E]
        cand_p = cand_p[..., :E]
    else:
        pad = [(0, 0)] * (cand_d.ndim - 1) + [(0, E - L)]
        cand_d = jnp.pad(cand_d, pad, constant_values=jnp.inf)
        cand_p = jnp.pad(cand_p, pad, constant_values=PAD_PAYLOAD)
    rd = cand_d[..., ::-1]
    rp = cand_p[..., ::-1]
    le = (beam_d < rd) | ((beam_d == rd) & (beam_p <= rp))
    md = jnp.where(le, beam_d, rd)
    mp = jnp.where(le, beam_p, rp)
    j = E // 2
    while j >= 1:
        md, mp = _cmp_swap(md, mp, j, True)
        j //= 2
    return md, mp


# --------------------------------------------------------------------- xla
@jax.jit
def beam_merge_xla(beam_d, beam_p, cand_d, cand_p):
    """Reference backend: the identical network as plain traced jnp."""
    cand_d, cand_p = _pad_candidates(cand_d, cand_p)
    return _merge_block(beam_d, beam_p, cand_d, cand_p)


# ------------------------------------------------------------------ pallas
def _kernel(bd_ref, bp_ref, cd_ref, cp_ref, od_ref, op_ref):
    nd, np_ = _merge_block(bd_ref[...], bp_ref[...], cd_ref[...], cp_ref[...])
    od_ref[...] = nd
    op_ref[...] = np_


def _pad_candidates(cand_d, cand_p):
    """Pad candidate length to a power of two (pad slots sort last)."""
    L = cand_d.shape[-1]
    Lp = next_pow2(max(L, 2))
    if Lp != L:
        pad = [(0, 0)] * (cand_d.ndim - 1) + [(0, Lp - L)]
        cand_d = jnp.pad(cand_d, pad, constant_values=jnp.inf)
        cand_p = jnp.pad(cand_p, pad, constant_values=PAD_PAYLOAD)
    return cand_d, cand_p


@functools.partial(jax.jit, static_argnames=("bb", "interpret"))
def beam_merge(
    beam_d: jnp.ndarray,   # (B, E) f32, ascending (E power of two)
    beam_p: jnp.ndarray,   # (B, E) int32 packed payloads
    cand_d: jnp.ndarray,   # (B, L) f32, +inf for invalid slots
    cand_p: jnp.ndarray,   # (B, L) int32
    *,
    bb: int = 8,
    interpret: bool = False,
):
    """Pallas backend: grid over row blocks, whole network in one kernel."""
    B, E = beam_d.shape
    if E & (E - 1):
        raise ValueError(f"beam width must be a power of two, got {E}")
    cand_d, cand_p = _pad_candidates(cand_d, cand_p)
    L = cand_d.shape[1]
    Bp = pad_to(B, bb)
    if Bp != B:
        rpad = ((0, Bp - B), (0, 0))
        beam_d = jnp.pad(beam_d, rpad, constant_values=jnp.inf)
        beam_p = jnp.pad(beam_p, rpad, constant_values=PAD_PAYLOAD)
        cand_d = jnp.pad(cand_d, rpad, constant_values=jnp.inf)
        cand_p = jnp.pad(cand_p, rpad, constant_values=PAD_PAYLOAD)
    out_d, out_p = pl.pallas_call(
        _kernel,
        grid=(Bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, E), lambda i: (i, 0)),
            pl.BlockSpec((bb, E), lambda i: (i, 0)),
            pl.BlockSpec((bb, L), lambda i: (i, 0)),
            pl.BlockSpec((bb, L), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, E), lambda i: (i, 0)),
            pl.BlockSpec((bb, E), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, E), jnp.float32),
            jax.ShapeDtypeStruct((Bp, E), jnp.int32),
        ],
        compiler_params=compiler_params(("arbitrary",)),
        interpret=interpret,
    )(beam_d, beam_p, cand_d, cand_p)
    return out_d[:B], out_p[:B]


# -------------------------------------------------------------- cost model
def merge_comparator_count(ef: int, M: int, *, width: int = 1, fused: bool = True) -> float:
    """Comparator ops per *expansion* for the beam-maintenance step.

    Legacy path: one full ``argsort`` of the ``(ef + M)`` concatenation per
    single-node expansion — modeled as a bitonic sort of the padded length.
    Fused path: sort ``L = next_pow2(width·M)`` candidates + one partial
    merge into the ``E = next_pow2(ef)`` beam, amortized over ``width``
    expansions.
    """
    import math

    def bitonic_sort_cost(n: int) -> float:
        lg = max(int(math.ceil(math.log2(n))), 1)
        return n / 2 * lg * (lg + 1) / 2

    if not fused:
        return bitonic_sort_cost(next_pow2(ef + M))
    E = next_pow2(ef)
    L = next_pow2(max(width * M, 2))
    merge = E + (E / 2) * max(int(math.log2(E)), 1)
    return (bitonic_sort_cost(L) + merge) / width
