"""Scalar-prefetch gather + distance Pallas TPU kernel (beam expansion).

The inner loop of Alg. 4 gathers the M neighbor rows of the expanded node and
scores them against the query.  On TPU the idiomatic pattern is a
``PrefetchScalarGridSpec``: the neighbor indices are scalar-prefetched, and
the corpus BlockSpec's ``index_map`` *reads them* to choose which (1, d) row
to DMA from HBM for each grid step — the gather happens in the pipeline, not
in the kernel body, so row fetches overlap with the previous step's compute.

Grid: ``(B, M)`` — one (query, neighbor) pair per step; the query row block
is reused across the M inner steps (same block index → no re-fetch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.util import compiler_params


def _kernel(idx_ref, q_ref, x_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)    # (1, d)
    x = x_ref[...].astype(jnp.float32)    # (1, d)
    diff = q - x
    o_ref[0, 0] = jnp.sum(diff * diff)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_sq_dist(
    x: jnp.ndarray,     # (n, d) corpus (stays in HBM; rows DMA'd on demand)
    idx: jnp.ndarray,   # (B, M) int32 neighbor ids (-1 = padding)
    q: jnp.ndarray,     # (B, d) queries
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """Squared L2 between q[b] and x[idx[b, m]]; +inf where idx < 0."""
    B, M = idx.shape
    d = x.shape[1]
    safe = jnp.clip(idx, 0, x.shape[0] - 1).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, M),
        in_specs=[
            pl.BlockSpec((1, d), lambda b, m, idx_ref: (b, 0)),
            pl.BlockSpec((1, d), lambda b, m, idx_ref: (idx_ref[b, m], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, m, idx_ref: (b, m)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, M), jnp.float32),
        compiler_params=compiler_params(("arbitrary", "arbitrary")),
        interpret=interpret,
    )(safe, q, x)
    return jnp.where(idx >= 0, out, jnp.inf)
