"""Fused expand-score kernel for the beam-search hot loop (Alg. 4 inner).

Every fused search step scores the ``C = W·M`` neighbor candidates of the
``W`` expanded frontier nodes against the query.  The pre-fusion path
materialized the full ``(B, C, d)`` candidate gather in HBM and ran one
batched matmul over it — at serving shapes (``B`` in the thousands,
``C = 128–512``, ``d`` up to 1536) that gather is the dominant per-step HBM
traffic of the query side, the exact quadratic-intermediate pattern the
build sweep already eliminated (DESIGN.md §9 → §10).

Three backends, dispatched via :func:`repro.kernels.ops.expand_score`:

* ``pallas`` — scalar-prefetch row gather: the ``(B, C)`` candidate ids are
  scalar-prefetched, and the corpus BlockSpec's ``index_map`` *reads them*
  to choose which ``(1, d)`` row to DMA from HBM for each ``(b, c)`` grid
  step.  The gather happens in the pipeline — each row fetch overlaps the
  previous step's compute — and the ``(B, C, d)`` tensor never exists.
  The query row block is reused across the ``C`` inner steps (same block
  index → no re-fetch).
* ``xla`` — the interpretable CPU-CI twin: a ``fori_loop`` over
  ``chunk``-wide candidate slices, peak intermediate ``(B, chunk, d)``.
* ``legacy`` — the pre-fusion baseline (full gather + matmul identity),
  kept for A/B profiling in ``bench_mixed_workload``.

Bit-identity contract (same reasoning as the prune sweep, DESIGN.md §9):
the fused backends compute each distance as an *elementwise*
square-difference sum over the feature axis, which is bitwise invariant
under any row blocking — per-row results do not depend on ``B``, ``C``,
the ``chunk`` width, or the batch composition.  That invariance is what
lets one mixed-semantics batch return bit-identical distances to four
per-semantics batches (DESIGN.md §10).  ``legacy`` uses the matmul
identity ``‖x‖² + ‖q‖² − 2·x·q`` whose reduction order is shape-dependent,
so it is only ever compared with ``allclose``.

Also here: the sort-based per-row first-occurrence dedup that replaces the
``O(C²)`` pairwise mask the search loop used to build twice per step (sort
by id, mask equal-adjacent, unsort — ``O(C log C)``, no ``(B, C, C)``
intermediate).  This module absorbs the former ``kernels/gather_dist.py``
(:func:`gather_sq_dist` is the same scalar-prefetch kernel, kept under its
historical name for the kernel microbenches).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.util import compiler_params


# ------------------------------------------------------------------ pallas
def _kernel(idx_ref, q_ref, x_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)    # (1, d)
    x = x_ref[...].astype(jnp.float32)    # (1, d) — the row idx_ref[b, c] chose
    diff = q - x
    o_ref[0, 0] = jnp.sum(diff * diff)


@functools.partial(jax.jit, static_argnames=("interpret",))
def expand_score(
    x: jnp.ndarray,     # (n, d) corpus (stays in HBM; rows DMA'd on demand)
    idx: jnp.ndarray,   # (B, C) int32 candidate ids (-1 = masked/padding)
    q: jnp.ndarray,     # (B, d) queries
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """Squared L2 between ``q[b]`` and ``x[idx[b, c]]``; ``+inf`` where
    ``idx < 0``.  One ``(1, d)`` corpus-row DMA per candidate, scheduled by
    the scalar-prefetched index array — no ``(B, C, d)`` intermediate."""
    B, C = idx.shape
    d = x.shape[1]
    safe = jnp.clip(idx, 0, x.shape[0] - 1).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, C),
        in_specs=[
            pl.BlockSpec((1, d), lambda b, c, idx_ref: (b, 0)),
            pl.BlockSpec((1, d), lambda b, c, idx_ref: (idx_ref[b, c], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, c, idx_ref: (b, c)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, C), jnp.float32),
        compiler_params=compiler_params(("arbitrary", "arbitrary")),
        interpret=interpret,
    )(safe, q, x)
    return jnp.where(idx >= 0, out, jnp.inf)


# Historical name from the absorbed kernels/gather_dist.py (microbenches,
# kernel sweep tests): same kernel, same semantics.
gather_sq_dist = expand_score


# -------------------------------------------------------------- pallas (int8)
def _kernel_q(idx_ref, q_ref, x_ref, s_ref, z_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)                  # (1, d)
    xq = x_ref[...].astype(jnp.float32)                 # (1, d) int8 row
    diff = q - (xq * s_ref[...] + z_ref[...])           # dequant in-register
    o_ref[0, 0] = jnp.sum(diff * diff)


@functools.partial(jax.jit, static_argnames=("interpret",))
def expand_score_q(
    x: jnp.ndarray,      # (n, d) int8 quantized corpus plane
    scale: jnp.ndarray,  # (d,) f32 per-dimension scale
    zero: jnp.ndarray,   # (d,) f32 per-dimension zero point
    idx: jnp.ndarray,    # (B, C) int32 candidate ids (-1 = masked/padding)
    q: jnp.ndarray,      # (B, d) queries
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """Quantized-plane :func:`expand_score`: the DMA'd ``(1, d)`` row is int8
    and dequantized in-register (``x·scale + zero``) before the square-diff
    sum — the f32 row never exists in HBM, so the per-step row traffic drops
    4× against the f32 plane.  Same scalar-prefetch schedule, same
    ``(B, C, d)``-free guarantee, and the same elementwise reduction that
    makes the XLA twin bit-identical under any chunking."""
    B, C = idx.shape
    d = x.shape[1]
    safe = jnp.clip(idx, 0, x.shape[0] - 1).astype(jnp.int32)
    s2 = scale.astype(jnp.float32).reshape(1, d)
    z2 = zero.astype(jnp.float32).reshape(1, d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, C),
        in_specs=[
            pl.BlockSpec((1, d), lambda b, c, idx_ref: (b, 0)),
            pl.BlockSpec((1, d), lambda b, c, idx_ref: (idx_ref[b, c], 0)),
            pl.BlockSpec((1, d), lambda b, c, idx_ref: (0, 0)),
            pl.BlockSpec((1, d), lambda b, c, idx_ref: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, c, idx_ref: (b, c)),
    )
    out = pl.pallas_call(
        _kernel_q,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, C), jnp.float32),
        compiler_params=compiler_params(("arbitrary", "arbitrary")),
        interpret=interpret,
    )(safe, q, x, s2, z2)
    return jnp.where(idx >= 0, out, jnp.inf)


@functools.partial(jax.jit, static_argnames=("chunk",))
def expand_score_q_xla(
    x: jnp.ndarray,      # (n, d) int8
    scale: jnp.ndarray,  # (d,) f32
    zero: jnp.ndarray,   # (d,) f32
    idx: jnp.ndarray,    # (B, C) int32, -1 = masked
    q: jnp.ndarray,      # (B, d)
    *,
    chunk: int = 32,
) -> jnp.ndarray:
    """CPU-CI twin of :func:`expand_score_q`: identical dequant + elementwise
    network over ``chunk``-wide candidate slices (peak ``(B, chunk, d)``,
    never ``(B, C, d)``); bit-identical to the Pallas kernel."""
    B, C = idx.shape
    n, d = x.shape
    q32 = q.astype(jnp.float32)
    s32 = scale.astype(jnp.float32)
    z32 = zero.astype(jnp.float32)
    chunk = max(min(chunk, (C + 1) // 2 if C > 1 else 1), 1)
    Cp = ((C + chunk - 1) // chunk) * chunk
    safe = jnp.clip(idx, 0, n - 1).astype(jnp.int32)
    if Cp != C:
        safe = jnp.pad(safe, ((0, 0), (0, Cp - C)))

    def body(t, acc):
        sl = jax.lax.dynamic_slice_in_dim(safe, t * chunk, chunk, axis=1)
        rows = x[sl].astype(jnp.float32)               # (B, chunk, d) int8→f32
        diff = q32[:, None, :] - (rows * s32 + z32)
        dc = jnp.sum(diff * diff, axis=-1)             # (B, chunk)
        return jax.lax.dynamic_update_slice_in_dim(acc, dc, t * chunk, axis=1)

    out = jax.lax.fori_loop(
        0, Cp // chunk, body, jnp.zeros((B, Cp), jnp.float32)
    )[:, :C]
    return jnp.where(idx >= 0, out, jnp.inf)


@jax.jit
def expand_score_q_legacy(
    x: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray,
    idx: jnp.ndarray, q: jnp.ndarray,
) -> jnp.ndarray:
    """Pre-fusion baseline on the quantized plane: materialize the dequantized
    ``(B, C, d)`` gather, score with the matmul identity (A/B profiling)."""
    n = x.shape[0]
    q32 = q.astype(jnp.float32)
    qn = jnp.sum(q32 * q32, axis=-1)
    safe = jnp.clip(idx, 0, n - 1)
    rows = x[safe].astype(jnp.float32) * scale.astype(jnp.float32) \
        + zero.astype(jnp.float32)                     # (B, C, d) gather
    xn = jnp.sum(rows * rows, axis=-1)
    ip = jnp.einsum("bcd,bd->bc", rows, q32)
    dist = jnp.maximum(xn + qn[:, None] - 2.0 * ip, 0.0)
    return jnp.where(idx >= 0, dist, jnp.inf)


# ---------------------------------------------------------------- pallas (pq)
def pq_lut(codebooks: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Per-query subspace distance tables: ``lut[b, j, k]`` is the squared
    L2 between query ``b``'s ``j``-th subvector and centroid ``k`` of
    subspace ``j`` — computed **once per batch** (ADC, Jégou et al. 2011).

    Each entry is an independent elementwise square-difference sum over the
    ``d/m`` subspace dims, so per-row tables are bitwise invariant under
    batch composition — the same invariance contract as the fused distance
    kernels (module docstring).  The transient ``(B, m, 256, d/m)`` diff
    is ``256·d·4`` bytes per query; at very large ``B·d`` it can be chunked
    over subspaces without changing a single bit (entries are independent).
    """
    B = q.shape[0]
    m, k, dsub = codebooks.shape
    qs = q.astype(jnp.float32).reshape(B, m, dsub)
    diff = qs[:, :, None, :] - codebooks[None]         # (B, m, K, dsub)
    return jnp.sum(diff * diff, axis=-1)               # (B, m, K)


def _fold_sum_m(vals: jnp.ndarray) -> jnp.ndarray:
    """Strict left-to-right sum over the last (subspace) axis.

    ``m`` is a small static constant, so this unrolls to a chain of adds.
    Both PQ backends reduce through this fold — a bare ``jnp.sum`` lets the
    compiler pick a backend-dependent association order over the ``m``
    lookups, which breaks the bit-identity contract (f32 adds don't
    reassociate)."""
    out = vals[..., 0]
    for j in range(1, vals.shape[-1]):
        out = out + vals[..., j]
    return out


def _kernel_pq(idx_ref, lut_ref, codes_ref, o_ref):
    lut = lut_ref[0]                                    # (m, K) — query b's tables
    code = codes_ref[0].astype(jnp.int32)               # (m,) — row idx_ref[b, c]
    vals = jnp.take_along_axis(lut, code[:, None], axis=1)[:, 0]  # (m,)
    o_ref[0, 0] = _fold_sum_m(vals)


@functools.partial(jax.jit, static_argnames=("interpret",))
def expand_score_pq(
    codes: jnp.ndarray,      # (n, m) uint8 PQ codes (stay in HBM)
    codebooks: jnp.ndarray,  # (m, 256, d/m) f32 frozen codebooks
    idx: jnp.ndarray,        # (B, C) int32 candidate ids (-1 = masked/padding)
    q: jnp.ndarray,          # (B, d) queries
    *,
    interpret: bool = False,
    lut: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """PQ-plane :func:`expand_score`: squared L2 between ``q[b]`` and the
    *decoded* row ``idx[b, c]``, without ever decoding it.  The per-query
    ``(m, 256)`` LUT is built once per batch (:func:`pq_lut`, or passed in
    precomputed by the fused search loop); each grid step then DMAs one
    ``(1, m)`` uint8 code row — the same scalar-prefetch schedule as the
    f32/int8 kernels — and sums ``m`` table lookups in-register.  Per-step
    row traffic drops from ``4d`` to ``m`` bytes and neither a ``(B, C, d)``
    gather nor a decoded ``(n, d)`` corpus ever exists."""
    B, C = idx.shape
    n, m = codes.shape
    k = codebooks.shape[1]
    if lut is None:
        lut = pq_lut(codebooks, q)
    safe = jnp.clip(idx, 0, n - 1).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, C),
        in_specs=[
            pl.BlockSpec((1, m, k), lambda b, c, idx_ref: (b, 0, 0)),
            pl.BlockSpec((1, m), lambda b, c, idx_ref: (idx_ref[b, c], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, c, idx_ref: (b, c)),
    )
    out = pl.pallas_call(
        _kernel_pq,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, C), jnp.float32),
        compiler_params=compiler_params(("arbitrary", "arbitrary")),
        interpret=interpret,
    )(safe, lut, codes)
    return jnp.where(idx >= 0, out, jnp.inf)


@functools.partial(jax.jit, static_argnames=("chunk",))
def expand_score_pq_xla(
    codes: jnp.ndarray,      # (n, m) uint8
    codebooks: jnp.ndarray,  # (m, 256, d/m) f32
    idx: jnp.ndarray,        # (B, C) int32, -1 = masked
    q: jnp.ndarray,          # (B, d)
    *,
    chunk: int = 32,
    lut: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """CPU-CI twin of :func:`expand_score_pq`: the same once-per-batch LUT
    (:func:`pq_lut`), then a ``fori_loop`` over ``chunk``-wide candidate
    slices gathering ``(B, chunk, m)`` uint8 code rows and summing the
    ``m`` table lookups per row.  Lookups index identical LUT entries and
    the sum runs over subspaces in the same order as the Pallas kernel, so
    the two are bit-identical for any ``chunk`` and batch composition."""
    B, C = idx.shape
    n, m = codes.shape
    if lut is None:
        lut = pq_lut(codebooks, q)                     # (B, m, K)
    chunk = max(min(chunk, (C + 1) // 2 if C > 1 else 1), 1)
    Cp = ((C + chunk - 1) // chunk) * chunk
    safe = jnp.clip(idx, 0, n - 1).astype(jnp.int32)
    if Cp != C:
        safe = jnp.pad(safe, ((0, 0), (0, Cp - C)))

    def body(t, acc):
        sl = jax.lax.dynamic_slice_in_dim(safe, t * chunk, chunk, axis=1)
        rows = codes[sl].astype(jnp.int32)             # (B, chunk, m) code rows
        vals = jnp.take_along_axis(                    # (B, chunk, m) lookups
            lut[:, None, :, :], rows[..., None], axis=-1
        )[..., 0]
        dc = _fold_sum_m(vals)                         # (B, chunk)
        return jax.lax.dynamic_update_slice_in_dim(acc, dc, t * chunk, axis=1)

    out = jax.lax.fori_loop(
        0, Cp // chunk, body, jnp.zeros((B, Cp), jnp.float32)
    )[:, :C]
    return jnp.where(idx >= 0, out, jnp.inf)


@jax.jit
def expand_score_pq_legacy(
    codes: jnp.ndarray, codebooks: jnp.ndarray,
    idx: jnp.ndarray, q: jnp.ndarray,
) -> jnp.ndarray:
    """Pre-fusion baseline on the PQ plane: decode the **entire corpus** to
    ``(n, d)`` f32, then the full ``(B, C, d)`` gather + matmul identity —
    both intermediates the fused pair exists to avoid (A/B profiling)."""
    n, m = codes.shape
    k, dsub = codebooks.shape[1:]
    flat = codebooks.reshape(m * k, dsub)
    offs = (jnp.arange(m, dtype=jnp.int32) * k)[None, :]
    dec = flat[codes.astype(jnp.int32) + offs].reshape(n, m * dsub)
    return expand_score_legacy(dec, idx, q)


# --------------------------------------------------------------------- xla
@functools.partial(jax.jit, static_argnames=("chunk",))
def expand_score_xla(
    x: jnp.ndarray,     # (n, d)
    idx: jnp.ndarray,   # (B, C) int32, -1 = masked
    q: jnp.ndarray,     # (B, d)
    *,
    chunk: int = 32,
) -> jnp.ndarray:
    """CPU-CI twin of :func:`expand_score`: identical elementwise network,
    traced as a ``fori_loop`` over ``chunk``-wide candidate slices so the
    peak intermediate is ``(B, chunk, d)`` — never ``(B, C, d)``.

    Bit-identical to the Pallas kernel for any ``chunk`` (elementwise
    per-row reduction; see module docstring)."""
    B, C = idx.shape
    n, d = x.shape
    q32 = q.astype(jnp.float32)
    # Never a single full-width chunk: chunk == C would materialize exactly
    # the (B, C, d) gather this twin exists to avoid.
    chunk = max(min(chunk, (C + 1) // 2 if C > 1 else 1), 1)
    Cp = ((C + chunk - 1) // chunk) * chunk
    safe = jnp.clip(idx, 0, n - 1).astype(jnp.int32)
    if Cp != C:
        safe = jnp.pad(safe, ((0, 0), (0, Cp - C)))

    def body(t, acc):
        sl = jax.lax.dynamic_slice_in_dim(safe, t * chunk, chunk, axis=1)
        rows = x[sl].astype(jnp.float32)               # (B, chunk, d)
        diff = q32[:, None, :] - rows
        dc = jnp.sum(diff * diff, axis=-1)             # (B, chunk)
        return jax.lax.dynamic_update_slice_in_dim(acc, dc, t * chunk, axis=1)

    out = jax.lax.fori_loop(
        0, Cp // chunk, body, jnp.zeros((B, Cp), jnp.float32)
    )[:, :C]
    return jnp.where(idx >= 0, out, jnp.inf)


# ------------------------------------------------------------------ legacy
@jax.jit
def expand_score_legacy(x: jnp.ndarray, idx: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Pre-fusion baseline: materialize the ``(B, C, d)`` gather, score with
    the matmul identity.  Kept for the A/B memory/QPS profile only."""
    n = x.shape[0]
    q32 = q.astype(jnp.float32)
    qn = jnp.sum(q32 * q32, axis=-1)
    xn = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)
    safe = jnp.clip(idx, 0, n - 1)
    rows = x[safe].astype(jnp.float32)                 # (B, C, d) gather
    ip = jnp.einsum("bcd,bd->bc", rows, q32)
    dist = jnp.maximum(xn[safe] + qn[:, None] - 2.0 * ip, 0.0)
    return jnp.where(idx >= 0, dist, jnp.inf)


# ------------------------------------------------------------------- dedup
def dedup_first(ids: jnp.ndarray, flag: jnp.ndarray) -> jnp.ndarray:
    """Per row, keep ``flag`` only on the first (lowest-index) flagged slot
    carrying each id — sort-based, ``O(C log C)``, no ``(·, C, C)`` tensor.

    Unflagged slots neither survive nor suppress later duplicates (they sort
    behind an id sentinel).  The stable argsort breaks equal-id ties by the
    original slot index, so "first of each sorted run" is exactly "lowest
    original index", matching :func:`dedup_first_quadratic` bit-for-bit.
    Integer-only: the id sort never touches the distance floats, which is
    why the search's bit-identity contract survives it (DESIGN.md §10).
    """
    sentinel = jnp.iinfo(jnp.int32).max
    key = jnp.where(flag, ids.astype(jnp.int32), sentinel)
    order = jnp.argsort(key, axis=-1, stable=True)
    sk = jnp.take_along_axis(key, order, axis=-1)
    run_start = jnp.concatenate(
        [jnp.ones(sk.shape[:-1] + (1,), bool), sk[..., 1:] != sk[..., :-1]],
        axis=-1,
    )
    keep_sorted = run_start & (sk != sentinel)
    inv = jnp.argsort(order, axis=-1)
    return jnp.take_along_axis(keep_sorted, inv, axis=-1)


def dedup_first_quadratic(ids: jnp.ndarray, flag: jnp.ndarray) -> jnp.ndarray:
    """The pre-fusion ``O(C²)`` pairwise-mask dedup (two ``(·, C, C)``
    boolean intermediates per call) — the oracle/baseline ``dedup_first``
    must match bit-for-bit."""
    C = ids.shape[-1]
    same = ids[..., :, None] == ids[..., None, :]          # (..., C, C)
    slot = jnp.arange(C, dtype=jnp.int32)
    earlier = slot[:, None] > slot[None, :]
    return flag & ~jnp.any(same & earlier & flag[..., None, :], axis=-1)
