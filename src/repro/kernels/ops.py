"""Jitted public wrappers around the Pallas kernels.

Backend dispatch: on CPU (this container) kernels run in ``interpret=True``
mode — the body executes in Python with identical semantics; on TPU they
compile through Mosaic.  Callers never pass ``interpret`` themselves.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import beam_merge as beam_merge_mod
from repro.kernels import expand_score as expand_score_mod
from repro.kernels import fused_scan, l2dist
from repro.kernels import prune_sweep as prune_sweep_mod
from repro.kernels.util import on_cpu


def resolve_backend(
    backend: str | None, *, choices: tuple[str, ...] = ("pallas", "xla")
) -> str:
    """Default kernel backend: Pallas on TPU, plain-jnp XLA on CPU CI."""
    if backend is None:
        return "xla" if on_cpu() else "pallas"
    if backend not in choices:
        raise ValueError(f"unknown kernel backend {backend!r} (choices {choices})")
    return backend


def pairwise_sq_dist(q: jnp.ndarray, x: jnp.ndarray, **kw) -> jnp.ndarray:
    """Blocked (nq, nx) squared-L2 distance matrix."""
    return l2dist.pairwise_sq_dist(q, x, interpret=on_cpu(), **kw)


def filtered_topk(
    q: jnp.ndarray,
    x: jnp.ndarray,
    obj_int: jnp.ndarray,
    q_int: jnp.ndarray,
    *,
    is_filter: bool,
    k: int,
    **kw,
):
    """Fused predicate + distance + exact top-k in one corpus pass."""
    return fused_scan.filtered_topk(
        q, x, obj_int, q_int, is_filter=is_filter, k=k, interpret=on_cpu(), **kw
    )


def expand_score(
    x: jnp.ndarray, idx: jnp.ndarray, q: jnp.ndarray, *, backend: str | None = None
) -> jnp.ndarray:
    """Beam-expansion scoring: squared L2 between ``q[b]`` and ``x[idx[b,c]]``
    (``+inf`` where ``idx < 0``).

    ``pallas`` scalar-prefetches the id array and DMAs one ``(1, d)`` corpus
    row per candidate (gather in the pipeline, never materialized); ``xla``
    is the bit-identical chunked elementwise twin; ``legacy`` the pre-fusion
    ``(B, C, d)`` gather + matmul baseline kept for A/B profiling.
    """
    resolved = resolve_backend(backend, choices=("pallas", "xla", "legacy"))
    if resolved == "legacy":
        return expand_score_mod.expand_score_legacy(x, idx, q)
    if resolved == "xla":
        return expand_score_mod.expand_score_xla(x, idx, q)
    return expand_score_mod.expand_score(x, idx, q, interpret=on_cpu())


def pq_lut(plane, q: jnp.ndarray) -> jnp.ndarray | None:
    """Per-query ``(m, 256)`` PQ distance tables for ``plane`` (None for
    non-pq planes).  The fused search loop calls this once per batch and
    hands the result to every :func:`expand_score_plane` step, so the LUT
    build is structurally loop-invariant — not merely hoisted by XLA."""
    if getattr(plane, "tag", None) != "pq":
        return None
    return expand_score_mod.pq_lut(plane.codebooks, q)


def expand_score_plane(
    plane, idx: jnp.ndarray, q: jnp.ndarray, *,
    backend: str | None = None, lut: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Beam-expansion scoring against a vector *plane* (core/store.py),
    dispatched on the plane's dtype tag.

    ``f32``/``bf16`` route through :func:`expand_score` unchanged (the row
    DMA casts in-register, so bf16 needs no twin); ``int8`` routes through
    the quantized kernels, which dequantize the ``(1, d)`` row in-register
    (``x·scale + zero``) — same scalar-prefetch schedule, same traced
    memory profile, 4× less row traffic.  ``pq`` routes through the
    LUT-based kernels: a per-query ``(m, 256)`` table built once per batch
    (pass ``lut`` from :func:`pq_lut` to share it across fused-loop steps),
    then one ``(1, m)`` uint8 code row DMA'd per candidate.  ``plane`` is
    duck-typed (``tag``/``data``/``scale``/``zero``/``codebooks``) so the
    kernels layer never imports core."""
    if plane.tag == "pq":
        resolved = resolve_backend(backend, choices=("pallas", "xla", "legacy"))
        if resolved == "legacy":
            return expand_score_mod.expand_score_pq_legacy(
                plane.data, plane.codebooks, idx, q)
        if resolved == "xla":
            return expand_score_mod.expand_score_pq_xla(
                plane.data, plane.codebooks, idx, q, lut=lut)
        return expand_score_mod.expand_score_pq(
            plane.data, plane.codebooks, idx, q, interpret=on_cpu(), lut=lut)
    if plane.tag != "int8":
        return expand_score(plane.data, idx, q, backend=backend)
    resolved = resolve_backend(backend, choices=("pallas", "xla", "legacy"))
    if resolved == "legacy":
        return expand_score_mod.expand_score_q_legacy(
            plane.data, plane.scale, plane.zero, idx, q)
    if resolved == "xla":
        return expand_score_mod.expand_score_q_xla(
            plane.data, plane.scale, plane.zero, idx, q)
    return expand_score_mod.expand_score_q(
        plane.data, plane.scale, plane.zero, idx, q, interpret=on_cpu())


def gather_sq_dist(x: jnp.ndarray, idx: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Beam-expansion scoring via scalar-prefetch row gather (historical
    name from the absorbed ``kernels/gather_dist.py``)."""
    return expand_score_mod.gather_sq_dist(x, idx, q, interpret=on_cpu())


def prune_sweep(
    i_u, xs, i_c, d_uc, valid, overlap,
    *,
    m_if: int,
    m_is: int,
    alpha: float = 1.0,
    unified: bool = True,
    backend: str | None = None,
    bb: int = 32,
):
    """Unified interval-aware pruning sweep (Alg. 3) over a node block.

    Returns ``(status int32, rep_if, rep_is)`` with repair slots local to
    the candidate axis.  All three backends run bit-identical scans:
    ``pallas`` tiles the batch ``bb`` rows per grid cell, ``xla`` traces the
    same block function over the whole batch, ``legacy`` materializes the
    ``(B, C, C)`` distance + Φ witness tensors before scanning (the
    pre-fusion baseline kept for A/B benchmarking).
    """
    resolved = resolve_backend(backend, choices=("pallas", "xla", "legacy"))
    kw = dict(m_if=m_if, m_is=m_is, alpha=alpha, unified=unified)
    if resolved == "legacy":
        return prune_sweep_mod.prune_sweep_legacy(
            i_u, xs, i_c, d_uc, valid, overlap, **kw
        )
    if resolved == "xla":
        return prune_sweep_mod.prune_sweep_xla(
            i_u, xs, i_c, d_uc, valid, overlap, **kw
        )
    return prune_sweep_mod.prune_sweep(
        i_u, xs, i_c, d_uc, valid, overlap, bb=bb, interpret=on_cpu(), **kw
    )


def beam_merge(beam_d, beam_p, cand_d, cand_p, *, backend: str | None = None):
    """Bitonic partial merge of scored candidates into the sorted ef-beam.

    Both backends run the identical compare-exchange network (bit-identical
    outputs): ``pallas`` through ``pallas_call`` (interpret on CPU),
    ``xla`` as plain traced jnp.
    """
    if resolve_backend(backend) == "xla":
        return beam_merge_mod.beam_merge_xla(beam_d, beam_p, cand_d, cand_p)
    return beam_merge_mod.beam_merge(
        beam_d, beam_p, cand_d, cand_p, interpret=on_cpu()
    )
