"""Jitted public wrappers around the Pallas kernels.

Backend dispatch: on CPU (this container) kernels run in ``interpret=True``
mode — the body executes in Python with identical semantics; on TPU they
compile through Mosaic.  Callers never pass ``interpret`` themselves.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import beam_merge as beam_merge_mod
from repro.kernels import fused_scan, gather_dist, l2dist
from repro.kernels.util import on_cpu


def resolve_backend(backend: str | None) -> str:
    """Default kernel backend: Pallas on TPU, plain-jnp XLA on CPU CI."""
    if backend is None:
        return "xla" if on_cpu() else "pallas"
    if backend not in ("pallas", "xla"):
        raise ValueError(f"unknown kernel backend {backend!r}")
    return backend


def pairwise_sq_dist(q: jnp.ndarray, x: jnp.ndarray, **kw) -> jnp.ndarray:
    """Blocked (nq, nx) squared-L2 distance matrix."""
    return l2dist.pairwise_sq_dist(q, x, interpret=on_cpu(), **kw)


def filtered_topk(
    q: jnp.ndarray,
    x: jnp.ndarray,
    obj_int: jnp.ndarray,
    q_int: jnp.ndarray,
    *,
    is_filter: bool,
    k: int,
    **kw,
):
    """Fused predicate + distance + exact top-k in one corpus pass."""
    return fused_scan.filtered_topk(
        q, x, obj_int, q_int, is_filter=is_filter, k=k, interpret=on_cpu(), **kw
    )


def gather_sq_dist(x: jnp.ndarray, idx: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Beam-expansion scoring via scalar-prefetch row gather."""
    return gather_dist.gather_sq_dist(x, idx, q, interpret=on_cpu())


def beam_merge(beam_d, beam_p, cand_d, cand_p, *, backend: str | None = None):
    """Bitonic partial merge of scored candidates into the sorted ef-beam.

    Both backends run the identical compare-exchange network (bit-identical
    outputs): ``pallas`` through ``pallas_call`` (interpret on CPU),
    ``xla`` as plain traced jnp.
    """
    if resolve_backend(backend) == "xla":
        return beam_merge_mod.beam_merge_xla(beam_d, beam_p, cand_d, cand_p)
    return beam_merge_mod.beam_merge(
        beam_d, beam_p, cand_d, cand_p, interpret=on_cpu()
    )
