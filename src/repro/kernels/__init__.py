"""Pallas TPU kernels for the paper's compute hot-spots (distance scans).

Each kernel has a pure-jnp oracle in ``ref.py`` and a jitted dispatching
wrapper in ``ops.py`` (interpret mode on CPU, Mosaic on TPU).
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
