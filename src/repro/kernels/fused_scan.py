"""Fused interval-filter + distance + running-top-k Pallas TPU kernel.

This is the paper's *pre-filtering* scan (and brute-force ground truth)
collapsed into one HBM pass: for each corpus tile the kernel computes
squared-L2 distances on the MXU, applies the interval predicate in-register,
and folds the tile into a per-query running top-k carried in the revisited
output block — the corpus is read exactly once, and no (nq × nx) distance
matrix ever exists in HBM.

Grid: ``(nq/bq, nx/bn)`` with the corpus axis **sequential** ("arbitrary")
so the output block (the running top-k) is revisited and stays resident in
VMEM across the whole scan.  Top-k maintenance is k rounds of
min-extract + sorted-insert — pure VPU ops (no in-kernel sort primitive
needed), negligible next to the (bq × bn × d) distance work for d ≥ 64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.util import compiler_params, pad_to


def _insert_sorted(vals, ids, m, mid):
    """Insert (m, mid) per row into the ascending (bq, k) carry."""
    k = vals.shape[1]
    pos = jnp.sum(vals < m[:, None], axis=1)            # (bq,)
    j = jax.lax.broadcasted_iota(jnp.int32, vals.shape, 1)
    shift_v = jnp.concatenate([vals[:, :1], vals[:, :-1]], axis=1)
    shift_i = jnp.concatenate([ids[:, :1], ids[:, :-1]], axis=1)
    take_new = j == pos[:, None]
    take_shift = j > pos[:, None]
    new_v = jnp.where(take_new, m[:, None], jnp.where(take_shift, shift_v, vals))
    new_i = jnp.where(take_new, mid[:, None], jnp.where(take_shift, shift_i, ids))
    return new_v, new_i


def _kernel(q_ref, x_ref, oi_ref, qi_ref, ov_ref, oid_ref, *, k, bn, is_filter, nx):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        ov_ref[...] = jnp.full_like(ov_ref, jnp.inf)
        oid_ref[...] = jnp.full_like(oid_ref, -1)

    q = q_ref[...].astype(jnp.float32)                   # (bq, d)
    x = x_ref[...].astype(jnp.float32)                   # (bn, d)
    ip = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    qn = jnp.sum(q * q, axis=1, keepdims=True)
    xn = jnp.sum(x * x, axis=1, keepdims=True).T
    d = jnp.maximum(qn + xn - 2.0 * ip, 0.0)             # (bq, bn)

    obj = oi_ref[...].astype(jnp.float32)                # (bn, 2)
    qi = qi_ref[...].astype(jnp.float32)                 # (bq, 2)
    if is_filter:  # IF/RF: object interval contained in query interval
        ok = (obj[None, :, 0] >= qi[:, None, 0]) & (obj[None, :, 1] <= qi[:, None, 1])
    else:          # IS/RS: object interval covers query interval
        ok = (obj[None, :, 0] <= qi[:, None, 0]) & (obj[None, :, 1] >= qi[:, None, 1])

    col = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1) + j * bn
    ok = ok & (col < nx)                                  # mask padding columns
    d = jnp.where(ok, d, jnp.inf)

    vals = ov_ref[...]
    ids = oid_ref[...]
    for _ in range(k):                                    # k min-extract rounds
        m = jnp.min(d, axis=1)                            # (bq,)
        am = jnp.argmin(d, axis=1)
        mid = jnp.take_along_axis(col, am[:, None], axis=1)[:, 0]
        mid = jnp.where(jnp.isfinite(m), mid, -1)
        vals, ids = _insert_sorted(vals, ids, m, mid)
        d = jnp.where(
            jax.lax.broadcasted_iota(jnp.int32, d.shape, 1) == am[:, None], jnp.inf, d
        )
    ov_ref[...] = vals
    oid_ref[...] = ids


@functools.partial(
    jax.jit, static_argnames=("is_filter", "k", "bq", "bn", "interpret")
)
def filtered_topk(
    q: jnp.ndarray,          # (nq, d)
    x: jnp.ndarray,          # (nx, d)
    obj_int: jnp.ndarray,    # (nx, 2)
    q_int: jnp.ndarray,      # (nq, 2)
    *,
    is_filter: bool,
    k: int,
    bq: int = 128,
    bn: int = 1024,
    interpret: bool = False,
):
    """Exact predicate-filtered top-k in a single fused HBM pass."""
    nq, d = q.shape
    nx = x.shape[0]
    bq = min(bq, pad_to(nq, 8))
    bn = min(bn, pad_to(nx, 128))
    qp = jnp.pad(q, ((0, pad_to(nq, bq) - nq), (0, 0)))
    xp = jnp.pad(x, ((0, pad_to(nx, bn) - nx), (0, 0)))
    oip = jnp.pad(obj_int, ((0, xp.shape[0] - nx), (0, 0)))
    qip = jnp.pad(q_int, ((0, qp.shape[0] - nq), (0, 0)))
    grid = (qp.shape[0] // bq, xp.shape[0] // bn)

    vals, ids = pl.pallas_call(
        functools.partial(_kernel, k=k, bn=bn, is_filter=is_filter, nx=nx),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 2), lambda i, j: (j, 0)),
            pl.BlockSpec((bq, 2), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),   # revisited carry
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp.shape[0], k), jnp.float32),
            jax.ShapeDtypeStruct((qp.shape[0], k), jnp.int32),
        ],
        compiler_params=compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(qp, xp, oip, qip)
    return vals[:nq], ids[:nq]
