"""Sharded checkpointing with async save, elastic restore, and UGIndex
round-trip (streaming allocator state included; DESIGN.md §11)."""
from repro.ckpt.store import (
    AsyncCheckpointer, latest_step, restore, restore_index, save, save_index,
)
