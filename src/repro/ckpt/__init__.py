"""Sharded checkpointing with async save and elastic restore."""
from repro.ckpt.store import AsyncCheckpointer, latest_step, restore, save
