"""Checkpointing: sharded save/restore with elastic re-sharding.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json     # tree structure, dtypes, step, data cursor, rng
        arrays/<key>.npy  # one file per leaf (path-flattened)

Restore takes *target shardings* — a checkpoint written on mesh A restores
onto mesh B (different device count / axis shapes) because leaves are saved
as full logical arrays and re-placed with ``jax.device_put`` under the new
``NamedSharding`` (the elastic-rescale path, see ``repro.ft.elastic``).
On a real pod the save gathers via multi-host-safe ``jax.device_get`` per
leaf, streaming one leaf at a time to bound host memory; saves can run on a
background thread (``async_save``) double-buffered against training.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import shutil
import threading
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = leaf
    return flat


def save(
    ckpt_dir: str | pathlib.Path,
    step: int,
    params,
    opt_state=None,
    *,
    data_cursor: int = 0,
    extra: dict | None = None,
    keep: int = 3,
) -> pathlib.Path:
    """Write one checkpoint; prunes old steps beyond ``keep``."""
    root = pathlib.Path(ckpt_dir)
    out = root / f"step_{step:09d}"
    tmp = root / f".tmp_step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    (tmp / "arrays").mkdir(parents=True)

    tree = {"params": params}
    if opt_state is not None:
        tree["opt"] = opt_state
    flat = _flatten(tree)
    meta = {
        "step": step,
        "data_cursor": data_cursor,
        "time": time.time(),
        "keys": {},
        "extra": extra or {},
    }
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace(_SEP, "__") + ".npy"
        np.save(tmp / "arrays" / fname, arr)
        meta["keys"][key] = {"file": fname, "dtype": str(arr.dtype), "shape": list(arr.shape)}
    (tmp / "manifest.json").write_text(json.dumps(meta))
    if out.exists():
        shutil.rmtree(out)
    tmp.rename(out)

    # prune
    steps = sorted(p for p in root.glob("step_*") if p.is_dir())
    for old in steps[:-keep]:
        shutil.rmtree(old, ignore_errors=True)
    return out


def latest_step(ckpt_dir: str | pathlib.Path) -> int | None:
    root = pathlib.Path(ckpt_dir)
    steps = sorted(p.name for p in root.glob("step_*") if p.is_dir())
    return int(steps[-1].split("_")[1]) if steps else None


def restore(
    ckpt_dir: str | pathlib.Path,
    step: int | None = None,
    *,
    params_template=None,
    opt_template=None,
    param_shardings=None,
    opt_shardings=None,
):
    """Load a checkpoint; optionally re-shard onto a (possibly new) mesh.

    Templates give the target pytree *structure*; shardings (same structure,
    prefix allowed) give placement.  Returns (params, opt_state, meta).
    """
    root = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    src = root / f"step_{step:09d}"
    meta = json.loads((src / "manifest.json").read_text())

    flat_arrays = {}
    for key, info in meta["keys"].items():
        flat_arrays[key] = np.load(src / "arrays" / info["file"])

    def rebuild(template, prefix, shardings):
        if template is None:
            return None
        leaves_with_path = jax.tree_util.tree_flatten_with_path(template)
        shard_flat = (
            _flatten(shardings) if shardings is not None else {}
        )
        out = []
        for path, leaf in leaves_with_path[0]:
            key = prefix + _SEP + _SEP.join(
                str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                for p in path
            )
            arr = flat_arrays[key]
            sub = _SEP.join(
                str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                for p in path
            )
            sh = shard_flat.get(sub)
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(leaves_with_path[1], out)

    params = rebuild(params_template, "params", param_shardings)
    opt = rebuild(opt_template, "opt", opt_shardings)
    return params, opt, meta


# ------------------------------------------------------------------ indexes
def save_index(ckpt_dir: str | pathlib.Path, step: int, index) -> pathlib.Path:
    """Checkpoint a (possibly mutated) UGIndex through the standard sharded
    store: the IndexStore's leaves become leaves under ``params/``, the
    build config, plane tag and allocator state ride in ``extra``
    (DESIGN.md §11/§12).  A streaming index's ``alive``/``free`` masks are
    materialized so the restored index resumes insert/delete exactly where
    the saved one stopped; quantization parameters round-trip bitwise (the
    codes are meaningless under any other scale/zero)."""
    st = index.store
    x_save = st.plane.data
    if st.plane.tag == "bf16":
        # numpy writes ml_dtypes bfloat16 as raw void ('|V2') and cannot
        # read it back: checkpoint the codes as a uint16 bit view (restore
        # re-casts keyed on the saved dtype tag).
        x_save = jnp.asarray(np.asarray(x_save).view(np.uint16))
    arrays = {
        "x": x_save,
        "intervals": st.intervals,
        "nbrs": st.nbrs,
        "status": st.status,
    }
    if st.plane.scale is not None:
        arrays["x_scale"] = st.plane.scale
        arrays["x_zero"] = st.plane.zero
    if st.plane.codebooks is not None:
        arrays["x_codebooks"] = st.plane.codebooks
    if st.rerank is not None:
        arrays["rerank"] = st.rerank.data
    streaming = st.alive is not None
    if streaming:
        arrays["alive"] = st.alive
        arrays["free"] = (
            jnp.zeros(st.alive.shape, bool) if st.free is None else st.free
        )
    extra = {
        "kind": "ug_index",
        "config": dataclasses.asdict(index.config),
        "build_seconds": index.build_seconds,
        "streaming": streaming,
        "dtype": st.plane.tag,
        "has_rerank": st.rerank is not None,
    }
    return save(ckpt_dir, step, arrays, extra=extra)


def restore_index(ckpt_dir: str | pathlib.Path, step: int | None = None):
    """Restore a UGIndex written by :func:`save_index`.

    The entry structure is rebuilt from the restored intervals under the
    restored ``alive`` mask, so a save → restore round trip of a mutated
    index searches bitwise identically to the live object
    (tests/test_updates_pipeline.py)."""
    from repro.core.build import UGConfig
    from repro.core.entry import build_entry_index
    from repro.core.index import UGIndex
    from repro.core.store import IndexStore, VectorPlane

    root = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    src = root / f"step_{step:09d}"
    meta = json.loads((src / "manifest.json").read_text())
    if meta["extra"].get("kind") != "ug_index":
        raise ValueError(f"checkpoint at {src} is not a ug_index checkpoint")

    keys = meta["keys"]

    def arr(key):
        info = keys[f"params/{key}"]
        return jnp.asarray(np.load(src / "arrays" / info["file"]))

    streaming = meta["extra"].get("streaming", False)
    alive = arr("alive") if streaming else None
    free = arr("free") if streaming else None
    intervals = arr("intervals")
    cfg = UGConfig(**meta["extra"]["config"])
    tag = meta["extra"].get("dtype", "f32")
    x_arr = arr("x")
    if tag == "bf16":  # stored as a uint16 bit view (see save_index)
        x_arr = x_arr.view(jnp.bfloat16)
    plane = VectorPlane(
        tag, x_arr,
        arr("x_scale") if "params/x_scale" in keys else None,
        arr("x_zero") if "params/x_zero" in keys else None,
        arr("x_codebooks") if "params/x_codebooks" in keys else None,
    )
    rerank = (
        VectorPlane("f32", arr("rerank"))
        if meta["extra"].get("has_rerank", False) else None
    )
    store = IndexStore(
        plane=plane, rerank=rerank, intervals=intervals,
        nbrs=arr("nbrs"), status=arr("status"),
        entry=build_entry_index(intervals, node_mask=alive),
        alive=alive, free=free,
    )
    return UGIndex(store, cfg, meta["extra"].get("build_seconds", 0.0))


class AsyncCheckpointer:
    """Background-thread checkpointing, double-buffered against training.

    ``save`` snapshots device arrays to host synchronously (cheap relative to
    a training step) and writes files on the worker thread; ``wait`` joins
    before the next save or at shutdown so at most one write is in flight.
    """

    def __init__(self, ckpt_dir: str | pathlib.Path, keep: int = 3):
        self.ckpt_dir = pathlib.Path(ckpt_dir)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_path: pathlib.Path | None = None

    def save(self, step: int, params, opt_state=None, **kw):
        self.wait()
        host_params = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), params)
        host_opt = (
            jax.tree.map(lambda a: np.asarray(jax.device_get(a)), opt_state)
            if opt_state is not None
            else None
        )

        def work():
            self.last_path = save(
                self.ckpt_dir, step, host_params, host_opt, keep=self.keep, **kw
            )

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
