"""Assigned-architecture configs (public literature; see each file's source
tag) + the paper's own index configs.  ``registry.get_arch(name)`` is the
single entry point used by --arch flags everywhere."""
from repro.configs.registry import ARCHS, ArchSpec, get_arch, list_archs
