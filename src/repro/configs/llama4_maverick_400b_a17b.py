"""llama4-maverick-400b-a17b [moe; hf:meta-llama/Llama-4-*; unverified]:
48L d=5120 40H (kv=8, head_dim=128) vocab=202048; MoE every other layer with
128 experts top-1 (d_ff=8192) + one shared expert; interleaved dense layers
use d_ff=16384.  Early-fusion vision (VQ-token stub)."""
import dataclasses
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="decoder",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=202048,
    moe=True, n_experts=128, top_k=1, moe_d_ff=8192, n_shared_experts=1,
    moe_every=2, dense_d_ff=16384,
    dtype=jnp.bfloat16, logits_chunk=128,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=64, moe_d_ff=64, dense_d_ff=128, n_experts=8, top_k=1,
        vocab=512, dtype=jnp.float32, logits_chunk=64,
    )
