"""starcoder2-15b [dense; arXiv:2402.19173; hf]: 40L d=6144 48H (kv=4,
head_dim=128) d_ff=24576 vocab=49152, GQA + RoPE."""
import dataclasses
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="decoder",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, head_dim=128,
    d_ff=24576, vocab=49152, gated_mlp=False, dtype=jnp.bfloat16,
    logits_chunk=512,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, dtype=jnp.float32, logits_chunk=64,
    )
