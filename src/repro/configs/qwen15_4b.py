"""qwen1.5-4b [dense; hf:Qwen/Qwen1.5-* family; hf]: 40L d=2560 20H (kv=20)
d_ff=6912 vocab=151936 with QKV bias (the qwen1.5 signature)."""
import dataclasses
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="decoder",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20, d_ff=6912,
    vocab=151936, qkv_bias=True, dtype=jnp.bfloat16, logits_chunk=256,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=512, dtype=jnp.float32, logits_chunk=64,
    )
