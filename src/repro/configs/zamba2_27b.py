"""zamba2-2.7b [hybrid; arXiv:2411.15242; hf]: 54L d=2560 Mamba2 backbone
(ssm_state=64) + a SHARED GQA attention block (32H kv=32, d_ff=10240)
applied every 6 layers.  Hybrid => runs the long_500k cell (attention KV
exists only at the 9 shared sites)."""
import dataclasses
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="zamba2",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab=32000, ssm_state=64, ssm_chunk=128, attn_every=6,
    dtype=jnp.bfloat16, logits_chunk=512,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=512, ssm_state=16, ssm_chunk=16, attn_every=2,
        dtype=jnp.float32, logits_chunk=64,
    )
