"""seamless-m4t-medium [audio; arXiv:2308.11596; hf]: enc-dec multimodal.
12L d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.  The speech frontend is
a STUB: input_specs provides precomputed frame embeddings to the encoder."""
import dataclasses
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, dtype=jnp.bfloat16, logits_chunk=128,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=512, dtype=jnp.float32, logits_chunk=64,
    )
