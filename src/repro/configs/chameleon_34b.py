"""chameleon-34b [vlm; arXiv:2405.09818; unverified]: early-fusion decoder,
VQ image tokens share the text vocab.  48L d=8192 64H (kv=8) d_ff=22016
vocab=65536, qk-norm (the chameleon training-stability fix)."""
import dataclasses
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="decoder",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab=65536, qk_norm=True, dtype=jnp.bfloat16, logits_chunk=256,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=512, dtype=jnp.float32, logits_chunk=64,
    )
