"""Architecture registry + the four assigned input shapes.

Every (arch × shape) cell is well-defined here; ``input_specs`` returns the
exact input pytree for the step the shape lowers (``train_step`` for
train_4k, ``prefill`` for prefill_32k, ``serve_step`` for decode_*/long_*) —
as real arrays (``concrete=True``, smoke tests / CPU runs) or as
ShapeDtypeStructs (dry-runs: no allocation).

Skips (DESIGN.md §5): ``long_500k`` requires sub-quadratic attention —
runnable only for rwkv6 (SSM, O(1) state) and zamba2 (hybrid); the 8 pure
full-attention archs skip it.  No assigned arch is encoder-only, so decode
shapes run everywhere.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

_SUBQUADRATIC = {"rwkv6-1.6b", "zamba2-2.7b"}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    module: str
    tag: str             # audio | vlm | moe | dense | ssm | hybrid

    @property
    def config(self) -> ModelConfig:
        return importlib.import_module(f"repro.configs.{self.module}").CONFIG

    @property
    def reduced(self) -> ModelConfig:
        return importlib.import_module(f"repro.configs.{self.module}").reduced()

    def skip_reason(self, shape: str) -> str | None:
        if shape == "long_500k" and self.name not in _SUBQUADRATIC:
            return (
                "long_500k needs sub-quadratic attention; "
                f"{self.name} is pure full-attention (DESIGN.md §5)"
            )
        return None


ARCHS: dict[str, ArchSpec] = {
    s.name: s
    for s in [
        ArchSpec("seamless-m4t-medium", "seamless_m4t_medium", "audio"),
        ArchSpec("chameleon-34b", "chameleon_34b", "vlm"),
        ArchSpec("qwen3-moe-235b-a22b", "qwen3_moe_235b_a22b", "moe"),
        ArchSpec("llama4-maverick-400b-a17b", "llama4_maverick_400b_a17b", "moe"),
        ArchSpec("minicpm3-4b", "minicpm3_4b", "dense"),
        ArchSpec("qwen1.5-4b", "qwen15_4b", "dense"),
        ArchSpec("qwen3-32b", "qwen3_32b", "dense"),
        ArchSpec("starcoder2-15b", "starcoder2_15b", "dense"),
        ArchSpec("rwkv6-1.6b", "rwkv6_16b", "ssm"),
        ArchSpec("zamba2-2.7b", "zamba2_27b", "hybrid"),
    ]
}


def get_arch(name: str) -> ArchSpec:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs() -> list[str]:
    return sorted(ARCHS)


# ---------------------------------------------------------------------------
# Input specs per (config, shape)
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(
    cfg: ModelConfig,
    shape: ShapeSpec,
    *,
    concrete: bool = False,
    batch_override: int | None = None,
    seq_override: int | None = None,
):
    """Input pytree for one cell.

    train   -> {"batch": {tokens, labels, mask[, frames]}}
    prefill -> {"tokens" [, "frames"]}
    decode  -> {"state": DecodeState-like pytree, "tokens": (B, 1)}
    """
    B = batch_override or shape.global_batch
    S = seq_override or shape.seq_len
    mk = (lambda s, d: jnp.zeros(s, d)) if concrete else _sds
    mki = (
        (lambda s, d: jnp.zeros(s, d)) if concrete else _sds
    )

    if shape.kind == "train":
        if cfg.family == "encdec":
            half = S // 2
            return {
                "frames": mk((B, half, cfg.d_model), jnp.float32),
                "tokens": mki((B, half), jnp.int32),
                "labels": mki((B, half), jnp.int32),
                "mask": mk((B, half), jnp.float32),
            }
        return {
            "tokens": mki((B, S), jnp.int32),
            "labels": mki((B, S), jnp.int32),
            "mask": mk((B, S), jnp.float32),
        }

    if shape.kind == "prefill":
        if cfg.family == "encdec":
            half = S // 2
            return {
                "frames": mk((B, half, cfg.d_model), jnp.float32),
                "tokens": mki((B, half), jnp.int32),
            }
        return {"tokens": mki((B, S), jnp.int32)}

    # decode: one new token against a cache of S
    state = decode_state_specs(cfg, B, S, concrete=concrete)
    return {"state": state, "tokens": mki((B, 1), jnp.int32)}


def decode_state_specs(cfg: ModelConfig, B: int, S: int, *, concrete: bool = False):
    """Decode-state pytree (ShapeDtypeStructs by default, arrays if concrete)."""
    from repro.models import encdec, rwkv_model, transformer, zamba

    if cfg.family == "decoder":
        fn = lambda: transformer.init_cache(cfg, B, S)
    elif cfg.family == "rwkv6":
        fn = lambda: rwkv_model.init_state(cfg, B, S)
    elif cfg.family == "zamba2":
        fn = lambda: zamba.init_state(cfg, B, S)
    elif cfg.family == "encdec":
        # self-attn cache at S plus precomputed cross-attn KV over S//8 frames
        enc_len = max(S // 8, 1)
        kv_shape = (cfg.n_layers, B, S, cfg.n_kv_heads, cfg.hd)
        x_shape = (cfg.n_layers, B, enc_len, cfg.n_kv_heads, cfg.hd)

        def fn():
            return encdec.EncDecState(
                (jnp.zeros(kv_shape, cfg.dtype), jnp.zeros(kv_shape, cfg.dtype)),
                (jnp.zeros(x_shape, cfg.dtype), jnp.zeros(x_shape, cfg.dtype)),
                jnp.zeros((B,), jnp.int32),
            )
    else:
        raise ValueError(cfg.family)
    if concrete:
        return fn()
    return jax.eval_shape(fn)
