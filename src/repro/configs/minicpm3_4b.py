"""minicpm3-4b [dense; hf:openbmb/MiniCPM3-4B; hf]: 62L d=2560 40H (kv=40)
d_ff=6400 vocab=73448 with MLA (multi-head latent attention): q_lora=768,
kv_lora=256, rope_head_dim=32, nope/v head_dim=64."""
import dataclasses
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="decoder",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
    d_ff=6400, vocab=73448,
    mla=True, q_lora_rank=768, kv_lora_rank=256, rope_head_dim=32,
    dtype=jnp.bfloat16, logits_chunk=512,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=512, q_lora_rank=32, kv_lora_rank=16, rope_head_dim=8,
        dtype=jnp.float32, logits_chunk=64,
    )
