"""rwkv6-1.6b 'Finch' [ssm; arXiv:2404.05892; unverified]: attention-free,
24L d=2048 (32 heads of 64) d_ff=7168 vocab=65536, data-dependent decay.
O(1) decode state => runs the long_500k cell."""
import dataclasses
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="rwkv6",
    n_layers=24, d_model=2048, n_heads=32, d_ff=7168, vocab=65536,
    ssm_chunk=128, dtype=jnp.bfloat16, logits_chunk=512,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab=512,
        ssm_chunk=16, dtype=jnp.float32, logits_chunk=64,
    )
