"""qwen3-32b [dense; hf:Qwen/Qwen3-* family; hf]: 64L d=5120 64H (kv=8,
head_dim=128) d_ff=25600 vocab=151936, qk-norm."""
import dataclasses
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b", family="decoder",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=25600, vocab=151936, qk_norm=True, dtype=jnp.bfloat16,
    logits_chunk=256,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, dtype=jnp.float32, logits_chunk=64,
    )
