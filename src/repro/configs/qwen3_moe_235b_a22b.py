"""qwen3-moe-235b-a22b [moe; hf:Qwen/Qwen3-30B-A3B scaled; hf]: 94L
d=4096 64H (kv=4, head_dim=128) vocab=151936, MoE 128 experts top-8 with
expert d_ff=1536 (fine-grained experts), qk-norm per qwen3."""
import dataclasses
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="decoder",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab=151936, qk_norm=True,
    moe=True, n_experts=128, top_k=8, moe_d_ff=1536,
    dtype=jnp.bfloat16, logits_chunk=256,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=64, moe_d_ff=64, n_experts=8, top_k=2, vocab=512,
        dtype=jnp.float32, logits_chunk=64,
    )
