"""Pipeline parallelism: GPipe-style microbatch pipelining over a mesh axis.

Completes the parallelism matrix (DP/TP/**PP**/EP/SP): layers are split into
``n_stages`` contiguous stages laid out along a mesh axis; microbatches flow
stage-to-stage via ``ppermute`` inside a ``shard_map``.  The schedule is the
classic GPipe loop of ``n_micro + n_stages - 1`` ticks — every stage computes
its resident microbatch then passes activations one hop right, so bubble
fraction = (S-1)/(M+S-1) and the collective per tick is exactly one
boundary activation per stage pair (point-to-point, no all-reduce).

This implementation targets *inference/forward* pipelining (the paper's
serving stack: embedding towers are deep, the index is downstream); for
training, stack it under ``jax.grad`` — ppermute is differentiable, and the
backward pass runs the reverse schedule automatically.

Stage-local layer weights are expected stacked as ``(n_stages, layers_per
_stage, ...)`` pytrees sharded ``P("stage", ...)`` on the pipeline axis.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map


def pipeline_forward(
    mesh: Mesh,
    axis: str,
    stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
    stage_params: Any,        # pytree, leaves (n_stages, ...) — sharded on axis
    x_micro: jnp.ndarray,     # (n_micro, mb, ...) microbatched input
):
    """Run ``stage_fn(params_stage, x) -> x`` through all stages.

    Returns (n_micro, mb, ...) outputs (as produced by the LAST stage).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def local(x_l, p_l):
        # x_l: (n_micro, mb, ...) replicated; p_l: (1, L/S, ...) this stage's slice
        p_stage = jax.tree.map(lambda a: a[0], p_l)
        sid = jax.lax.axis_index(axis)

        mb_shape = x_l.shape[1:]
        buf = jnp.zeros(mb_shape, x_l.dtype)      # activation resident here
        outs = jnp.zeros_like(x_l)                 # completed microbatches

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (when available)
            feed = x_l[jnp.clip(t, 0, n_micro - 1)]
            buf = jnp.where((sid == 0) & (t < n_micro), feed, buf)
            # compute if this stage holds a live microbatch: stage s works on
            # microbatch (t - s) when 0 <= t - s < n_micro
            live = (t - sid >= 0) & (t - sid < n_micro)
            y = stage_fn(p_stage, buf)
            buf = jnp.where(live, y, buf)
            # the last stage retires microbatch (t - n_stages + 1)
            done_idx = t - n_stages + 1
            outs = jax.lax.cond(
                (sid == n_stages - 1) & (done_idx >= 0),
                lambda o: o.at[jnp.clip(done_idx, 0, n_micro - 1)].set(buf),
                lambda o: o,
                outs,
            )
            # shift activations one stage right
            buf = jax.lax.ppermute(buf, axis, perm)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
        # only the last stage holds real outputs; broadcast them
        outs = jax.lax.psum(
            jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs)), axis
        )
        return outs

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(axis)),
        out_specs=P(),
        check_vma=False,
    )
    return fn(x_micro, stage_params)


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    """GPipe bubble overhead: (S-1) / (M + S - 1)."""
    return (n_stages - 1) / (n_micro + n_stages - 1)
