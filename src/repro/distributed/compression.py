"""Gradient compression: int8 quantized all-reduce with error feedback.

For cross-pod data parallelism the DP all-reduce crosses the slow inter-pod
links; int8 block-quantization cuts those bytes 4× (bf16→int8 plus a fp32
scale per block).  Error feedback (Seide et al.; 1-bit SGD lineage) keeps
the quantization noise from biasing convergence: the residual between the
true and quantized gradient is carried into the next step.

Used inside ``shard_map`` (explicit-DP) contexts; the baseline jit path
keeps XLA's native bf16 all-reduce.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


class EFState(NamedTuple):
    residual: Any  # same pytree as grads, fp32


def init_ef(grads_template) -> EFState:
    return EFState(
        jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_template)
    )


def _quantize(x: jnp.ndarray):
    """Per-block symmetric int8 quantization of a flat fp32 vector."""
    n = x.shape[0]
    pad = (-n) % BLOCK
    xp = jnp.pad(x, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(xp), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xp / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), n


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray, n: int) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).reshape(-1)[:n]


def compressed_psum(grads, ef: EFState, axis_name: str):
    """int8 all-reduce with error feedback; call inside shard_map.

    Returns (mean gradients, new EF state).  The collective moves int8
    payloads + one fp32 scale per 256 elements (≈ 4.06× fewer bytes than
    fp32, 2.03× fewer than bf16).
    """
    size = jax.lax.psum(1, axis_name)

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        flat = g32.reshape(-1)
        q, scale, n = _quantize(flat)
        deq_local = _dequantize(q, scale, n)
        new_r = flat - deq_local                     # error feedback residual
        # all-reduce the dequantized payload: on real hardware the int8
        # tensor itself is summed (psum over int32-accumulated int8); we
        # model the same numerics by summing dequantized values.
        q_sum = jax.lax.psum(deq_local, axis_name)
        return (q_sum / size).reshape(g.shape).astype(g.dtype), new_r.reshape(g.shape)

    out = jax.tree.map(one, grads, ef.residual)
    mean_g = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return mean_g, EFState(new_res)


def compression_ratio(n_elements: int) -> float:
    """Bytes(bf16) / bytes(int8+scales) for an n-element tensor."""
    bf16 = 2 * n_elements
    blocks = (n_elements + BLOCK - 1) // BLOCK
    comp = n_elements + 4 * blocks
    return bf16 / comp
