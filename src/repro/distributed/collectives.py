"""Hand-rolled overlap-friendly collectives (ring all-gather / reduce-scatter
via ``ppermute``) for shard_map code paths.

XLA already emits tuned collectives for jit-traced code; these exist for the
places where we *schedule* communication ourselves to overlap with compute —
the ring-streamed KNN build (core/sharded.py) and the §Perf experiments that
compare one-shot vs ring schedules (each ring hop's ppermute can execute
concurrently with the consumer's matmul on the previously received block).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro import compat


def ring_all_gather(x: jnp.ndarray, axis_name: str):
    """All-gather along ``axis_name`` as n-1 ppermute hops.

    Returns (size, x_full) where x_full has a new leading shard axis in ring
    order starting at the local shard.
    """
    size = compat.axis_size(axis_name)
    perm = [(i, (i + 1) % size) for i in range(size)]

    def step(carry, _):
        blk = carry
        nxt = jax.lax.ppermute(blk, axis_name, perm)
        return nxt, blk

    _, blocks = jax.lax.scan(step, x, None, length=size)
    return size, blocks  # (size, *x.shape), blocks[0] == local shard


def ring_reduce_scatter(x: jnp.ndarray, axis_name: str):
    """Reduce-scatter (sum) of a (size, chunk, ...) array along the ring.

    Each rank ends with the fully-reduced chunk ``x[rank]``.
    """
    size = compat.axis_size(axis_name)
    me = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % size) for i in range(size)]

    def step(carry, k):
        acc = carry  # running partial for the chunk we'll pass on
        # the partial arriving at hop k belongs to chunk (me - k - 2) mod n:
        # chunk c starts at rank c+1 and completes at rank c after n-1 hops
        idx = (me - k - 2) % size
        acc = jax.lax.ppermute(acc, axis_name, perm) + x[idx]
        return acc, None

    init = x[(me - 1) % size]   # chunk me-1 starts its journey here
    acc, _ = jax.lax.scan(step, init, jnp.arange(size - 1))
    return acc


def ring_streamed_map(
    x_block: jnp.ndarray,
    axis_name: str,
    fold: Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray],
    init,
):
    """Stream every rank's block past every other rank (the KNN-build pattern).

    ``fold(acc, visiting_block, src_rank) -> acc`` runs once per hop while
    the next ppermute is in flight (overlap by construction: the permute's
    result is not needed until the next iteration).
    """
    size = compat.axis_size(axis_name)
    me = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % size) for i in range(size)]

    def step(carry, k):
        blk, acc = carry
        src = (me - k) % size
        acc = fold(acc, blk, src)
        blk = jax.lax.ppermute(blk, axis_name, perm)
        return (blk, acc), None

    (_, acc), _ = jax.lax.scan(step, (x_block, init), jnp.arange(size))
    return acc
