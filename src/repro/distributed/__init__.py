"""Distribution utilities: compression, ring collectives."""
from repro.distributed.compression import (EFState, compressed_psum,
                                           compression_ratio, init_ef)
from repro.distributed.collectives import (ring_all_gather,
                                           ring_reduce_scatter,
                                           ring_streamed_map)
