"""Distributed (row-sharded) index serving on 8 simulated devices.

The corpus is split over a (data=4, model=2) mesh; each shard runs local
interval-aware beam search; per-shard top-k merge via all_gather — the same
shard_map program the 512-chip dry-run lowers (launch/dryrun.py --index-cell).

Run:  PYTHONPATH=src python examples/distributed_serve.py
(sets XLA_FLAGS itself; run in a fresh process)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Semantics, UGConfig, brute_force, recall
from repro.core import intervals as iv
from repro.core.search import SearchResult
from repro.core.sharded import (build_sharded_store, make_ring_knn_fn,
                                make_sharded_search_fn)
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))
print(f"mesh: {dict(mesh.shape)} over {len(jax.devices())} devices")

k1, k2, k3, k4 = jax.random.split(jax.random.key(0), 4)
n, d = 4000, 24
x = np.asarray(jax.random.normal(k1, (n, d)))
ints = np.asarray(iv.sample_uniform_intervals(k2, n))

cfg = UGConfig(ef_spatial=24, ef_attribute=48, max_edges_if=24, max_edges_is=24,
               iterations=2, repair_width=8, exact_spatial=True, block=1024)
t0 = time.perf_counter()
# On-device sharded build (DESIGN.md §12): one shard_map program constructs
# all 4 shard-local UGs in parallel — ring-KNN bootstrap + shard-local
# attribute orders + the same jitted prune/repair iterations build_ug runs.
sidx = build_sharded_store(mesh, x, ints, cfg, index_axes=("data",))
jax.block_until_ready(sidx.store.nbrs)
print(f"built 4 shard-local UGs on-device in {time.perf_counter()-t0:.1f}s "
      "(heredity => shard-local graphs are sound)")

nq = 64
qv = jax.random.normal(k3, (nq, d))
c = jax.random.uniform(k4, (nq, 1))
qi = jnp.concatenate([jnp.maximum(c - .3, 0), jnp.minimum(c + .3, 1)], axis=1)

for sem in (Semantics.IF, Semantics.IS):
    fn = make_sharded_search_fn(mesh, index_axes=("data",), sem=sem, ef=64, k=10)
    ids, dist = fn(sidx, qv, qi)
    jax.block_until_ready(ids)
    t0 = time.perf_counter()
    ids, dist = fn(sidx, qv, qi)
    jax.block_until_ready(ids)
    dt = time.perf_counter() - t0
    gt = brute_force(jnp.asarray(x), jnp.asarray(ints), qv, qi, sem=sem, k=10)
    r = recall(SearchResult(ids, dist, None), gt)
    print(f"{sem.value}: recall@10 = {r:.3f}   QPS = {nq/dt:,.0f}")

# int8 scan plane + f32 rerank: 4x less per-vector scan traffic, same top-k
sidx8 = build_sharded_store(mesh, x, ints, cfg, index_axes=("data",),
                            dtype="int8", rerank=True)
fn8 = make_sharded_search_fn(mesh, index_axes=("data",), sem=Semantics.IF,
                             ef=64, k=10, plane_tag="int8", has_rerank=True)
ids8, dist8 = fn8(sidx8, qv, qi)
gt = brute_force(jnp.asarray(x), jnp.asarray(ints), qv, qi, sem=Semantics.IF, k=10)
print(f"int8+rerank IF recall@10 = "
      f"{recall(SearchResult(ids8, dist8, None), gt):.3f} "
      f"({sidx8.store.plane.bytes_per_vector():.1f} scan B/vec)")

# bonus: the ring-streamed exact KNN builder (collective_permute pipeline)
ring = make_ring_knn_fn(mesh, axis="data", k=8)
ri, _ = ring(sidx.store.plane.data, sidx.global_ids)
print(f"ring-streamed exact KNN over {n} rows: done, shape {ri.shape}")
