"""End-to-end interval-aware retrieval with an LM tower (the paper's
deployment scenario): embed -> unified index -> IF/IS/RF/RS queries.

Run:  PYTHONPATH=src python examples/interval_search_e2e.py
This is a thin wrapper over launch/serve.py with a small default scale.
"""
from repro.launch.serve import main

raise SystemExit(main(["--arch", "qwen1.5-4b", "--docs", "1500",
                       "--queries", "48", "--doc-len", "24"]))
