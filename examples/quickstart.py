"""Quickstart: build a unified interval-aware index, query all 4 semantics.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import Semantics, UGConfig, UGIndex, recall
from repro.data import CorpusConfig, make_corpus, make_queries

# 1. a corpus of vectors, each with a validity interval [l, r] ⊆ [0, 1]
ccfg = CorpusConfig(n=3000, dim=32, seed=0)
x, intervals = make_corpus(ccfg)

# 2. ONE unified index (paper Alg. 1-3): per-edge IF/IS semantic bitmask
cfg = UGConfig(ef_spatial=32, ef_attribute=64, max_edges_if=32,
               max_edges_is=32, iterations=3, exact_spatial=True)
index = UGIndex.build(x, intervals, cfg)
print(f"built UG over {index.n} vectors in {index.build_seconds:.1f}s; "
      f"degrees: {index.degree_stats()}")

# 3. the same index answers all four query semantics (paper §2.1)
qv, q_win = make_queries(ccfg, 32, workload="uniform")   # interval queries
_, q_point = make_queries(ccfg, 32, workload="point")    # timestamp queries

for sem, q in [
    (Semantics.IF, q_win),    # results' intervals inside the query window
    (Semantics.IS, q_win),    # results' intervals covering the window
    (Semantics.RS, q_point),  # results alive at a timestamp
    (Semantics.RF, q_win),    # scalar-attribute range filter
]:
    res = index.search(qv, q, sem=sem, ef=64, k=10)
    gt = index.ground_truth(qv, q, sem=sem, k=10)
    print(f"{sem.value}: recall@10 = {recall(res, gt):.3f}  "
          f"mean graph hops = {float(res.steps.mean()):.1f}")

# 4. ...or serve all four from ONE batch: semantics are runtime state, so a
#    mixed IF/IS/RS/RF stream shares a single compiled program (DESIGN.md §10)
sems = [Semantics.IF, Semantics.IS, Semantics.RS, Semantics.RF] * 8
q_mixed = jnp.where(
    jnp.asarray([s is Semantics.RS for s in sems])[:, None], q_point, q_win)
mixed = index.search_mixed(qv, q_mixed, sems, ef=64, k=10)
print(f"mixed 4-semantics batch: {mixed.ids.shape[0]} queries in "
      f"{int(mixed.iters)} fused iterations")
