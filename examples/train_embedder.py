"""End-to-end driver (deliverable b): train a ~100M-param LM for a few
hundred steps with checkpointing + straggler monitoring, then use it as the
embedding tower for the interval-aware index.

Run:  PYTHONPATH=src python examples/train_embedder.py [--steps 200]
(On this CPU container ~100M params is the practical 'real' scale; the same
script drives any --arch at full scale on a pod via launch/train.py.)
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.ckpt import AsyncCheckpointer
from repro.core import Semantics, UGConfig, UGIndex, recall
from repro.core import intervals as iv
from repro.data import LMDataConfig, lm_batch
from repro.ft import StepTimer
from repro.models import ModelConfig, get_model
from repro.serve import ServeEngine
from repro.train import AdamWConfig, make_train_step, optim

p = argparse.ArgumentParser()
p.add_argument("--steps", type=int, default=60)
p.add_argument("--ckpt", default="/tmp/repro_ckpt")
args = p.parse_args()

# ~35M params (the largest that trains briskly on this 1-core container;
# pass --steps/--arch scale on a pod via launch/train.py)
cfg = ModelConfig(family="decoder", n_layers=6, d_model=512, n_heads=8,
                  n_kv_heads=4, d_ff=1408, vocab=32000, dtype=jnp.float32,
                  remat=False, logits_chunk=128)
model = get_model(cfg)
print(f"params: {cfg.param_count():,}")

params = model.init(jax.random.key(0))
ocfg = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
opt_state = optim.init(ocfg, params)
step = make_train_step(model, ocfg, donate=False)
data = LMDataConfig(vocab=cfg.vocab, batch=4, seq=128)
ckpt = AsyncCheckpointer(args.ckpt)
timer = StepTimer()

for s in range(args.steps):
    t0 = time.perf_counter()
    params, opt_state, m = step(params, opt_state, lm_batch(data, s))
    jax.block_until_ready(m["loss"])
    timer.record(time.perf_counter() - t0)
    if s % 20 == 0:
        print(f"step {s:4d}  loss {float(m['loss']):.4f}  lr {float(m['lr']):.2e}")
    if (s + 1) % 100 == 0:
        ckpt.save(s + 1, params, opt_state, data_cursor=s + 1)
ckpt.wait()
print("training done; embedding a corpus with the trained tower...")

engine = ServeEngine(model, params)
docs = jax.random.randint(jax.random.key(5), (1500, 64), 0, cfg.vocab)
embs = jnp.concatenate([engine.embed(docs[i:i + 256]) for i in range(0, 1500, 256)])
ints = iv.sample_uniform_intervals(jax.random.key(6), 1500)
index = UGIndex.build(embs, ints, UGConfig(
    ef_spatial=24, ef_attribute=48, max_edges_if=24, max_edges_is=24,
    iterations=2, exact_spatial=True))
qv = engine.embed(jax.random.randint(jax.random.key(7), (16, 64), 0, cfg.vocab))
c = jax.random.uniform(jax.random.key(8), (16, 1))
qi = jnp.concatenate([jnp.maximum(c - .3, 0), jnp.minimum(c + .3, 1)], axis=1)
res = index.search(qv, qi, sem=Semantics.IF, ef=64, k=10)
gt = index.ground_truth(qv, qi, sem=Semantics.IF, k=10)
print(f"retrieval over trained embeddings: IF recall@10 = {recall(res, gt):.3f}")
