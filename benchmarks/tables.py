"""One benchmark function per paper table/figure (DESIGN.md §7 index).

Each returns CSV-able rows: name, us_per_call, derived.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import Semantics, UGConfig, UGIndex, recall
from repro.core.build import build_ug
from repro.core.search import brute_force
from repro.data import CorpusConfig, make_corpus, make_queries


# ---------------------------------------------------------------- Exp-1 / Fig 6
def bench_ifann(n=common.N_DEFAULT):
    """IFANN QPS–recall trade-off: UG vs post-filter vs Hi-PNG vs pre-filter."""
    rows = []
    qv, qi = common.queries("uniform", n=n)
    ug = common.ug_index(n)
    pf = common.postfilter_index(n)
    hp = common.hipng_index(n)
    gt = ug.ground_truth(qv, qi, sem=Semantics.IF, k=10)

    for ef in (16, 32, 64, 128):
        qps, r = common.qps_recall(ug, qv, qi, sem=Semantics.IF, ef=ef)
        rows.append(common.row(f"ifann_ug_ef{ef}", 1e6 / qps, f"recall={r:.3f} qps={qps:.0f}"))
    for ef in (32, 128):
        dt, res = common.timed(
            lambda: pf.search(qv, qi, sem=Semantics.IF, ef=ef, k=10, oversample=8)
        )
        r = recall(res, gt)
        rows.append(common.row(f"ifann_postfilter_ef{ef}", 1e6 * dt / qv.shape[0],
                               f"recall={r:.3f} qps={qv.shape[0]/dt:.0f}"))
    dt, res = common.timed(lambda: hp.search(qv, qi, ef=64, k=10))
    rows.append(common.row("ifann_hipng_ef64", 1e6 * dt / qv.shape[0],
                           f"recall={recall(res, gt):.3f} qps={qv.shape[0]/dt:.0f}"))
    x, ints = common.corpus(n)
    dt, res = common.timed(
        lambda: brute_force(x, ints, qv, qi, sem=Semantics.IF, k=10)
    )
    rows.append(common.row("ifann_prefilter_exact", 1e6 * dt / qv.shape[0],
                           f"recall=1.000 qps={qv.shape[0]/dt:.0f}"))
    return rows


# ---------------------------------------------------------------- Exp-2 / Fig 7
def bench_query_types(n=common.N_DEFAULT):
    """One UG index answering all four semantics (the paper's headline)."""
    rows = []
    ug = common.ug_index(n)
    qv, qi = common.queries("uniform", n=n)
    _, qpoint = common.queries("point", n=n)
    for sem, q in [
        (Semantics.IF, qi), (Semantics.IS, qi),
        (Semantics.RS, qpoint), (Semantics.RF, qi),
    ]:
        qps, r = common.qps_recall(ug, qv, q, sem=sem, ef=96)
        rows.append(common.row(f"qtype_{sem.value.lower()}", 1e6 / qps,
                               f"recall={r:.3f} qps={qps:.0f}"))
    return rows


# ---------------------------------------------------------------- Exp-3 / Fig 10
def bench_workloads(n=common.N_DEFAULT):
    """IFANN under short/long/mixed/uniform selectivity workloads."""
    rows = []
    ug = common.ug_index(n)
    for w in ("short", "long", "mixed", "uniform"):
        qv, qi = common.queries(w, n=n)
        qps, r = common.qps_recall(ug, qv, qi, sem=Semantics.IF, ef=96)
        rows.append(common.row(f"workload_{w}", 1e6 / qps,
                               f"recall={r:.3f} qps={qps:.0f}"))
    return rows


# ---------------------------------------------------------------- Exp-4 / Fig 8+9
def bench_indexing(n=common.N_DEFAULT):
    """Index construction time and memory for UG vs baselines."""
    rows = []
    ug = common.ug_index(n)
    rows.append(common.row("index_build_ug", ug.build_seconds * 1e6,
                           f"seconds={ug.build_seconds:.1f} bytes={ug.memory_bytes():,}"))
    pf = common.postfilter_index(n)
    rows.append(common.row("index_build_postfilter", pf.build_seconds * 1e6,
                           f"seconds={pf.build_seconds:.1f}"))
    hp = common.hipng_index(n)
    rows.append(common.row("index_build_hipng", hp.build_seconds * 1e6,
                           f"seconds={hp.build_seconds:.1f} partitions={len(hp.partitions)}"))
    d = ug.degree_stats()
    rows.append(common.row("index_degrees_ug", 0.0,
                           f"mean_if={d['mean_if']:.1f} mean_is={d['mean_is']:.1f} edges={d['edges']}"))
    return rows


# ---------------------------------------------------------------- Exp-5 / Fig 12
def bench_k(n=common.N_DEFAULT):
    rows = []
    ug = common.ug_index(n)
    qv, qi = common.queries("uniform", n=n)
    for k in (1, 10, 20, 50):
        qps, r = common.qps_recall(ug, qv, qi, sem=Semantics.IF, ef=max(96, 2 * k), k=k)
        rows.append(common.row(f"vary_k_{k}", 1e6 / qps,
                               f"recall={r:.3f} qps={qps:.0f}"))
    return rows


# ---------------------------------------------------------------- Exp-6 / Fig 11
def bench_sensitivity(n=2000):
    """Build-parameter sensitivity (smaller n: builds many indexes)."""
    rows = []
    x, ints = common.corpus(n)
    qv, qi = common.queries("uniform", n=n)

    def build_and_eval(tag, **kw):
        cfg_kw = dict(ef_spatial=24, ef_attribute=48, max_edges_if=24,
                      max_edges_is=24, iterations=2, repair_width=8,
                      exact_spatial=True, block=1024)
        cfg_kw.update(kw)
        idx = UGIndex.build(x, ints, UGConfig(**cfg_kw))
        qps, r = common.qps_recall(idx, qv, qi, sem=Semantics.IF, ef=64)
        rows.append(common.row(f"sens_{tag}", idx.build_seconds * 1e6,
                               f"recall={r:.3f} qps={qps:.0f} build_s={idx.build_seconds:.1f}"))

    for efa in (16, 48, 96):
        build_and_eval(f"ef_attr_{efa}", ef_attribute=efa)
    for efs in (8, 24, 48):
        build_and_eval(f"ef_spatial_{efs}", ef_spatial=efs)
    for it in (1, 2, 4):
        build_and_eval(f"iters_{it}", iterations=it)
    for me in (8, 24, 48):
        build_and_eval(f"max_edges_{me}", max_edges_if=me, max_edges_is=me)
    return rows


# ---------------------------------------------------------------- Exp-7 / Fig 13
def bench_scalability(sizes=(1000, 2000, 4000, 8000)):
    rows = []
    for n in sizes:
        idx = common.ug_index(n)
        qv, qi = common.queries("uniform", n=n)
        qps, r = common.qps_recall(idx, qv, qi, sem=Semantics.IF, ef=64)
        rows.append(common.row(f"scale_n{n}", 1e6 / qps,
                               f"recall={r:.3f} qps={qps:.0f} build_s={idx.build_seconds:.1f}"))
    return rows


# ------------------------------------------------- fused multi-expansion sweep
def bench_beam_sweep(n=common.N_DEFAULT):
    """QPS-vs-recall of the fused multi-expansion pipeline vs the legacy
    argsort loop, over all four semantics (DESIGN.md §8).

    Derived column reports recall, QPS, mean expansions, and the analytic
    merge-comparator cost per expansion — the fused path must be strictly
    below legacy (no full ``(ef+M)`` argsort in the hot loop).

    CPU wall-clock note: the fused pipeline is batch-synchronous and
    lane-parallel (TPU-shaped); on CPU its while_loop runs to the slowest
    query and the comparator network gets no vector units, so legacy wins
    wall-clock here.  The comparator model is the hardware-independent
    signal; per-shape QPS crossover is a TPU measurement (DESIGN.md §6).
    """
    from repro.kernels.beam_merge import merge_comparator_count

    rows = []
    ug = common.ug_index(n)
    qv, qi = common.queries("uniform", n=n)
    _, qpoint = common.queries("point", n=n)
    M = ug.graph.nbrs.shape[1]
    width = 4
    for sem, q in [
        (Semantics.IF, qi), (Semantics.IS, qi),
        (Semantics.RS, qpoint), (Semantics.RF, qi),
    ]:
        for ef in (32, 96):
            gt = ug.ground_truth(qv, q, sem=sem, k=10)
            for backend in ("legacy", "xla"):
                w = 1 if backend == "legacy" else width
                dt, res = common.timed(
                    lambda: ug.search(qv, q, sem=sem, ef=ef, k=10,
                                      backend=backend, width=w),
                    iters=1,
                )
                r = recall(res, gt)
                cmps = merge_comparator_count(
                    ef, M, width=w, fused=backend != "legacy")
                rows.append(common.row(
                    f"beam_{sem.value.lower()}_{backend}_ef{ef}",
                    1e6 * dt / qv.shape[0],
                    f"recall={r:.3f} qps={qv.shape[0]/dt:.0f} "
                    f"hops={float(res.steps.mean()):.1f} "
                    f"merge_cmp_per_expansion={cmps:.0f}"))
    return rows


# ------------------------------------------------- mixed-workload serving
def bench_mixed_workload(n=common.N_DEFAULT, require_speedup=None):
    """Runtime-semantics serving: one interleaved IF/IS/RF/RS batch through
    the single compiled mixed program vs the same traffic as four
    quarter-size per-semantics batches (DESIGN.md §10).

    Derived columns report, for both schedules: wall-clock QPS,
    per-semantics recall, and the **batch-synchronous QPS model** — the
    fused pipeline's latency on lane-parallel hardware is (shared
    while_loop iterations) × (per-step latency, B-independent up to the
    lane count), so the interleaved/split speedup is ``Σ_s iters_s /
    iters_mixed``, measured from the real programs' iteration counters
    (``SearchResult.iters``).  The mixed batch runs exactly
    ``max_s iters_s`` iterations (row independence), while four split
    batches serialize all four loops.  ``require_speedup`` (used by
    ``run.py --smoke``) asserts the sync-model speedup.

    CPU wall-clock note (same caveat as ``bench_beam_sweep``): on CPU the
    per-iteration cost grows ~linearly with B (no vector lanes to absorb
    the batch), so split quarter batches can win wall-clock here; the
    iteration-count model is the hardware-independent signal and the
    wall-clock crossover is a TPU measurement (DESIGN.md §6).

    Also asserted: the traced per-step intermediate profile of the
    expand/dedup pair — the new path must show no ``(B, C, d)`` candidate
    gather and no ``(·, C, C)`` dedup tensor (the ISSUE-3 acceptance
    check), and mixed-batch ids must equal the per-semantics programs'
    bitwise.
    """
    from repro.core.search import search_step_memory_profile

    rows = []
    # -- per-step memory profile, old expand/dedup pair vs new
    for backend in ("legacy", "xla", "pallas"):
        prof = search_step_memory_profile(backend)
        if backend != "legacy":
            assert not prof["gather_bcd"] and not prof["quadratic_cc"], (
                f"{backend} search step materializes a quadratic intermediate")
        rows.append(common.row(
            f"mixed_step_profile_{backend}", 0.0,
            f"peak_intermediate_bytes={prof['peak_bytes']} "
            f"bcd_gather={'yes' if prof['gather_bcd'] else 'no'} "
            f"cc_dedup={'yes' if prof['quadratic_cc'] else 'no'}"))

    ug = common.ug_index(n)
    qv, qi = common.queries("uniform", n=n)
    _, qpoint = common.queries("point", n=n)
    nq = qv.shape[0]
    cycle = [Semantics.IF, Semantics.IS, Semantics.RS, Semantics.RF]
    sems = [cycle[i % 4] for i in range(nq)]
    is_rs = jnp.asarray([s is Semantics.RS for s in sems])
    qm = jnp.where(is_rs[:, None], qpoint, qi)
    subsets = {s: np.asarray([i for i, ss in enumerate(sems) if ss is s])
               for s in cycle}
    ef = 96

    # -- interleaved: one program, one batch
    dt_mixed, res_mixed = common.timed(
        lambda: ug.search_mixed(qv, qm, sems, ef=ef, k=10))

    # -- split: the same traffic as four per-semantics quarter batches
    # (keyed by sem value: enum keys are not sortable as a jax pytree)
    def run_split():
        return {s.value: ug.search(qv[sel], qm[sel], sem=s, ef=ef, k=10)
                for s, sel in subsets.items()}

    dt_split, res_split = common.timed(run_split)

    mixed_ids = np.asarray(res_mixed.ids)
    recalls = {}
    for s, sel in subsets.items():
        gt = ug.ground_truth(qv[sel], qm[sel], sem=s, k=10)
        recalls[s] = recall(
            type(res_mixed)(res_mixed.ids[sel], res_mixed.dist[sel],
                            res_mixed.steps[sel]), gt)
        # runtime-semantics contract: the mixed batch answers exactly as the
        # per-semantics program would
        assert np.array_equal(mixed_ids[sel], np.asarray(res_split[s.value].ids)), s

    # batch-synchronous latency model from the measured iteration counters
    iters_mixed = int(res_mixed.iters)
    iters_split = sum(int(res_split[s.value].iters) for s in cycle)
    sync_speedup = iters_split / max(iters_mixed, 1)
    wall_speedup = dt_split / dt_mixed
    qps_mixed = nq / dt_mixed
    qps_split = nq / dt_split
    rec = " ".join(f"recall_{s.value.lower()}={recalls[s]:.3f}" for s in cycle)
    rows.append(common.row(
        "mixed_interleaved_4sem", 1e6 * dt_mixed / nq,
        f"cpu_qps={qps_mixed:.0f} sync_iters={iters_mixed} {rec} "
        f"hops={float(res_mixed.steps.mean()):.1f}"))
    rows.append(common.row(
        "mixed_split_4x_per_sem", 1e6 * dt_split / nq,
        f"cpu_qps={qps_split:.0f} sync_iters={iters_split} "
        f"sync_speedup_interleaved={sync_speedup:.2f}x "
        f"cpu_wall_speedup={wall_speedup:.2f}x"))
    if require_speedup is not None:
        assert sync_speedup >= require_speedup, (
            f"interleaved mixed batch only {sync_speedup:.2f}x fewer "
            f"batch-synchronous iterations than four per-semantics batches "
            f"(need >= {require_speedup}x)")
    return rows


# ------------------------------------------------- construction-cost sweep
def bench_build(sizes=(1000, 2000, 4000), backends=("legacy", "xla", "pallas")):
    """Construction cost per prune backend vs n (DESIGN.md §9).

    Reports wall-clock build seconds plus the traced peak single
    intermediate of one pruning sweep — the fused backends must never
    materialize a ``(B, C, C)`` Φ/distance tensor, which is asserted here
    (the ISSUE-2 acceptance criterion), while ``legacy`` keeps the
    quadratic tensors so the table quantifies exactly what fusion removes.
    All backends build byte-identical graphs (test_prune_sweep.py), so the
    derived column also carries a graph checksum as a cross-backend guard.
    """
    from repro.core.candidates import candidate_pool_width
    from repro.kernels.prune_sweep import sweep_memory_profile

    rows = []
    cfg_base = common.UG_CFG
    # Sweep-shape profile at the build's actual tile shape: cfg.block rows
    # per lax.map tile; the widest candidate axis is the iteration-0 pool.
    pool_c = candidate_pool_width(cfg_base.ef_spatial, cfg_base.ef_attribute)
    profiles = {}
    for backend in backends:
        prof = sweep_memory_profile(
            backend, B=cfg_base.block, C=pool_c,
            d=common.DIM, m_if=cfg_base.max_edges_if, m_is=cfg_base.max_edges_is,
        )
        if backend != "legacy":
            assert not prof["quadratic"], (
                f"{backend} sweep materializes a (B, C, C) tensor")
        profiles[backend] = prof
        rows.append(common.row(
            f"build_sweep_profile_{backend}", 0.0,
            f"peak_intermediate_bytes={prof['peak_bytes']} "
            f"phi_materialized={'yes' if prof['quadratic'] else 'no'}"))

    for n in sizes:
        x, ints = common.corpus(n)
        for backend in backends:
            cfg = dataclasses.replace(cfg_base, prune_backend=backend)
            dt, graph = common.timed(
                lambda: build_ug(jax.random.key(0), x, ints, cfg),
                warmup=0, iters=1,
            )
            checksum = int(np.asarray(graph.nbrs, np.int64).sum()) \
                + int(np.asarray(graph.status, np.int64).sum())
            rows.append(common.row(
                f"build_{backend}_n{n}", dt * 1e6,
                f"seconds={dt:.1f} edges={int((np.asarray(graph.nbrs) >= 0).sum())} "
                f"graph_checksum={checksum} "
                f"peak_sweep_bytes={profiles[backend]['peak_bytes']}"))
    # On-device sharded build (DESIGN.md §12): the large-n path
    # (run.py --n 1e6+) — every shard's graph constructed by one shard_map
    # program.  Timed over all local devices at the largest requested size.
    from jax.sharding import Mesh

    from repro.core.sharded import build_sharded_store

    n_sh = max(sizes)
    devs = np.asarray(jax.devices())
    x, ints = common.corpus(n_sh)
    mesh = Mesh(devs, ("data",))
    dt, sidx = common.timed(
        lambda: build_sharded_store(
            mesh, np.asarray(x), np.asarray(ints), cfg_base, dtype="pq"),
        warmup=0, iters=1,
    )
    rows.append(common.row(
        f"build_sharded_n{n_sh}", dt * 1e6,
        f"seconds={dt:.1f} shards={len(devs)} "
        f"rows={int(sidx.global_ids.shape[0])} dtype=pq"))
    return rows


# ------------------------------------------------- streaming updates (churn)
def bench_updates(n=common.N_DEFAULT, churn=0.1, require_recall_gap=None):
    """Streaming-update subsystem (DESIGN.md §11): churn throughput +
    recall-vs-fresh-rebuild across all four semantics.

    Deletes ``churn·n`` random nodes (tombstone + iterative repair), inserts
    ``churn·n`` fresh ones through the batched jitted pipeline, and compares
    recall@10 on the mutated index against a from-scratch rebuild over the
    same live corpus.  ``require_recall_gap`` (used by ``run.py --smoke``)
    asserts ``recall_mutated ≥ recall_fresh − gap`` per semantics.

    Also asserted: the traced-jaxpr profile of the insert and repair
    programs — the fused path must materialize no ``(·, C, C)`` witness /
    dedup tensor and no ``(B, C, d)`` search / bridge gather, while
    ``legacy`` (pre-fusion prune + expand baselines) shows both.
    """
    from repro.core.updates import update_memory_profile

    rows = []
    for backend in ("legacy", "xla", "pallas"):
        prof = update_memory_profile(backend)
        if backend != "legacy":
            assert not prof["quadratic_cc"] and not prof["gather_bcd"], (
                f"{backend} update pipeline materializes a quadratic "
                f"intermediate")
        rows.append(common.row(
            f"updates_profile_{backend}", 0.0,
            f"peak_intermediate_bytes={prof['peak_bytes']} "
            f"cc_witness={'yes' if prof['quadratic_cc'] else 'no'} "
            f"bcd_gather={'yes' if prof['gather_bcd'] else 'no'}"))

    x, ints = common.corpus(n)
    k_new = jax.random.key(1234)
    b = max(int(n * churn), 1)
    new_x = jax.random.normal(jax.random.fold_in(k_new, 0), (b, x.shape[1]))
    from repro.core import intervals as iv_mod

    new_iv = iv_mod.sample_uniform_intervals(jax.random.fold_in(k_new, 1), b)
    rng = np.random.default_rng(42)
    dels = jnp.asarray(rng.choice(n, size=b, replace=False).astype(np.int32))

    idx0 = UGIndex.build(x, ints, common.UG_CFG)

    # timed churn (one warmup pass for jit, then the measured pass); the
    # UGIndex dataclass is not a pytree, so block on the graph explicitly
    def run_del():
        out = idx0.delete(dels)
        jax.block_until_ready(out.graph.nbrs)
        return out

    dt_del, idx_d = common.timed(run_del, warmup=1, iters=1)

    def run_ins():
        out = idx_d.insert(new_x, new_iv)
        jax.block_until_ready(out.graph.nbrs)
        return out

    dt_ins, idx_m = common.timed(run_ins, warmup=1, iters=1)
    rows.append(common.row(
        "updates_delete_batch", 1e6 * dt_del / b,
        f"deletes_per_s={b/dt_del:.0f} batch={b} live={idx_m.n}"))
    rows.append(common.row(
        "updates_insert_batch", 1e6 * dt_ins / b,
        f"inserts_per_s={b/dt_ins:.0f} batch={b} capacity={idx_m.capacity}"))

    # fresh rebuild over the mutated corpus (the recall yardstick)
    keep = np.setdiff1d(np.arange(n), np.asarray(dels))
    x_f = jnp.concatenate([x[jnp.asarray(keep)], new_x])
    iv_f = jnp.concatenate([ints[jnp.asarray(keep)], new_iv])
    idx_f = UGIndex.build(x_f, iv_f, common.UG_CFG)

    qv, qi = common.queries("uniform", n=n)
    _, qpoint = common.queries("point", n=n)
    worst = 0.0
    for sem, q in [
        (Semantics.IF, qi), (Semantics.IS, qi),
        (Semantics.RS, qpoint), (Semantics.RF, qi),
    ]:
        dt_q, res = common.timed(
            lambda: idx_m.search(qv, q, sem=sem, ef=96, k=10))
        r_mut = recall(res, idx_m.ground_truth(qv, q, sem=sem, k=10))
        r_fresh = recall(
            idx_f.search(qv, q, sem=sem, ef=96, k=10),
            idx_f.ground_truth(qv, q, sem=sem, k=10),
        )
        gap = r_fresh - r_mut
        worst = max(worst, gap)
        rows.append(common.row(
            f"updates_churn_{sem.value.lower()}", 1e6 * dt_q / qv.shape[0],
            f"recall={r_mut:.3f} recall_fresh_rebuild={r_fresh:.3f} "
            f"gap={gap:+.3f} qps={qv.shape[0]/dt_q:.0f}"))
    if require_recall_gap is not None:
        assert worst <= require_recall_gap, (
            f"churned-index recall trails a fresh rebuild by {worst:.3f} "
            f"(allowed {require_recall_gap})")
    return rows


# ------------------------------------------------- vector-plane memory tiers
def bench_memory(n=common.N_DEFAULT, require_reduction=None,
                 require_pq_reduction=8.0):
    """Bytes/vector vs recall vs QPS per vector plane (DESIGN.md §12/§14).

    One graph, six stores: the f32 scan plane, its bf16 / int8 / pq
    re-encodings, and int8/pq + the exact f32 rerank plane.  Recall is
    always measured against the *f32* brute-force truth on the shared
    graph, so the table reads directly as the bytes/vec-vs-recall-vs-QPS
    frontier.  Reported plane bytes amortize over *live* rows (codebook /
    qparam overhead included, so the pq figure converges to ``d/8`` as n
    grows).  ``require_reduction`` (run.py --smoke) asserts the ISSUE-5
    acceptance pair (int8 scan bytes ≥ that factor below f32, int8+rerank
    recall within 0.02 of f32); ``require_pq_reduction`` asserts the
    ISSUE-7 pair: pq *codes* ≥ 8x below f32 rows AND pq+rerank recall
    within 0.05 of f32.
    """
    rows = []
    ug = common.ug_index(n)
    qv, qi = common.queries("uniform", n=n)
    gt = ug.ground_truth(qv, qi, sem=Semantics.IF, k=10)
    variants = [
        ("f32", ug),
        ("bf16", ug.with_dtype("bf16")),
        ("int8", ug.with_dtype("int8", rerank=False)),
        ("int8_rerank", ug.with_dtype("int8", rerank=True)),
        ("pq", ug.with_dtype("pq", rerank=False)),
        ("pq_rerank", ug.with_dtype("pq", rerank=True)),
    ]
    recalls = {}
    plane_b = {}
    for tag, idx in variants:
        dt, res = common.timed(
            lambda idx=idx: idx.search(qv, qi, sem=Semantics.IF, ef=96, k=10))
        r = recall(res, gt)
        recalls[tag] = r
        plane_b[tag] = idx.store.plane.bytes_per_vector(idx.n)
        rr = idx.store.rerank
        rows.append(common.row(
            f"memory_{tag}", 1e6 * dt / qv.shape[0],
            f"recall={r:.3f} plane_bytes={plane_b[tag]:.0f} "
            f"rerank_bytes={0 if rr is None else rr.bytes_per_vector(idx.n):.0f} "
            f"qps={qv.shape[0]/dt:.0f}"))
    reduction = plane_b["f32"] / plane_b["int8_rerank"]
    gap = recalls["f32"] - recalls["int8_rerank"]
    pq_plane = variants[-1][1].store.plane
    pq_codes = pq_plane.data.shape[0] * pq_plane.data.shape[1]
    pq_code_red = (plane_b["f32"] * n) / pq_codes    # codes only, no overhead
    pq_gap = recalls["f32"] - recalls["pq_rerank"]
    rows.append(common.row(
        "memory_summary", 0.0,
        f"int8_scan_reduction={reduction:.2f} "
        f"int8_rerank_recall_gap={gap:+.3f} "
        f"pq_code_reduction={pq_code_red:.2f} "
        f"pq_rerank_recall_gap={pq_gap:+.3f}"))
    if require_reduction is not None:
        assert reduction >= require_reduction, (
            f"int8 scan plane only {reduction:.2f}x below f32 bytes/vector "
            f"(need >= {require_reduction}x)")
        assert gap <= 0.02, (
            f"int8+rerank trails f32 recall by {gap:.3f} (allowed 0.02)")
    if require_pq_reduction is not None:
        assert pq_code_red >= require_pq_reduction, (
            f"pq codes only {pq_code_red:.2f}x below f32 rows "
            f"(need >= {require_pq_reduction}x)")
        assert pq_gap <= 0.05, (
            f"pq+rerank trails f32 recall by {pq_gap:.3f} (allowed 0.05)")
    return rows


# ------------------------------------------------------- async serve runtime
def bench_serve(n=common.N_DEFAULT, nreq=256, batch=64, require_qps_ratio=None):
    """Async continuous-batching runtime vs the sync batched path
    (DESIGN.md §13) on a churning mixed IF/IS/RF/RS workload.

    One request stream, served twice from the same initial index: the sync
    path processes FIFO batches of ``batch`` through ``retrieve_mixed``
    (blocking per batch), the async path trickles the same requests one at
    a time through :class:`~repro.serve.runtime.ServeRuntime`.  Halfway
    through, both paths apply the same churn write (remove + upsert).
    Functional updates are deterministic, so both paths' post-write
    snapshots are bitwise-identical — which makes the consistency metrics
    exact equality checks, not tolerances:

    * ``recall_vs_pinned_snapshot`` — fraction of async replies bitwise-
      equal to a direct ``search_mixed`` on the snapshot the reply pinned
      (1.0 == no torn reads); the ``recall`` prefix puts it under the
      baseline gate's floor;
    * ``recall_async_eq_sync`` — fraction of requests where async and sync
      answers agree bitwise (continuous batching is exact);
    * ``recall_pre``/``recall_post`` — recall@10 of each stream half
      against its own snapshot's brute-force truth.

    ``require_qps_ratio`` (run.py --smoke) asserts
    ``qps_async ≥ ratio · qps_sync``.
    """
    from repro.core import intervals as iv_mod
    from repro.core.search import search_mixed
    from repro.serve import RuntimeConfig, ServeEngine, ServeRuntime
    from repro.serve.engine import bucket_batch_size

    ef, k = 64, 10
    x, ints = common.corpus(n)
    idx0 = common.ug_index(n)

    cycle = [Semantics.IF, Semantics.IS, Semantics.RS, Semantics.RF]
    sems = [cycle[i % 4] for i in range(nreq)]
    qv, q_wide = common.queries("uniform", n=n, nq=nreq)
    _, q_point = common.queries("point", n=n, nq=nreq)
    is_rs = jnp.asarray([s is Semantics.RS for s in sems])
    qw = jnp.where(is_rs[:, None], q_point, q_wide)

    b_churn = max(n // 20, 8)
    rng = np.random.default_rng(77)
    dels = jnp.asarray(rng.choice(n, size=b_churn, replace=False).astype(np.int32))
    new_x = jax.random.normal(jax.random.key(4321), (b_churn, x.shape[1]))
    new_iv = iv_mod.sample_uniform_intervals(jax.random.key(4322), b_churn)
    mid = (nreq // batch // 2) * batch

    def serve_sync(engine):
        """FIFO batches, blocking per batch; churn write between batches."""
        out_ids, out_dist = [], []
        t0 = time.perf_counter()
        for s in range(0, nreq, batch):
            if s == mid:
                engine.remove(dels)
                engine.upsert(None, new_iv, x=new_x)
            res = engine.retrieve_mixed(
                None, qw[s:s + batch], sems[s:s + batch], ef=ef, k=k,
                q_v=qv[s:s + batch])
            out_ids.append(np.asarray(res.ids))   # blocks: sync semantics
            out_dist.append(np.asarray(res.dist))
        dt = time.perf_counter() - t0
        return np.concatenate(out_ids), np.concatenate(out_dist), dt

    # warmup pass on a scratch engine: compiles every program both measured
    # paths touch, so neither measured pass pays compile time.  The sync
    # path only ever sees the ``batch`` bucket, but the async coalescer
    # dequeues whatever run lengths the race with admission produces — warm
    # every bucket up to ``batch``, on both the pre- and post-churn store
    # layouts (churn attaches the alive mask, a different program pytree).
    def warm_buckets(engine):
        m, top = 1, bucket_batch_size(batch)
        while True:
            m = bucket_batch_size(m)
            engine.retrieve_mixed(None, qw[:m], sems[:m], ef=ef, k=k,
                                  q_v=qv[:m])
            if m >= top:
                break
            m += 1

    scratch = ServeEngine(None, None)
    scratch.attach_index(idx0)
    warm_buckets(scratch)
    serve_sync(scratch)     # update programs + post-churn batch-bucket search
    warm_buckets(scratch)   # post-churn layout, remaining buckets

    eng_sync = ServeEngine(None, None)
    eng_sync.attach_index(idx0)
    ids_sync, dist_sync, dt_sync = serve_sync(eng_sync)
    qps_sync = nreq / dt_sync

    eng_async = ServeEngine(None, None)
    eng_async.attach_index(idx0)
    # requests arrive as individual vectors; materialize the rows before the
    # clock starts so both paths time serving, not harness slicing
    q_rows = [qv[i] for i in range(nreq)]
    w_rows = [qw[i] for i in range(nreq)]
    t0 = time.perf_counter()
    with ServeRuntime(eng_async, RuntimeConfig(max_batch=batch)) as rt:
        futs, wfuts = [], []
        for i in range(nreq):
            if i == mid:
                wfuts.append(rt.submit_remove(dels))
                wfuts.append(rt.submit_upsert(new_x, new_iv))
            futs.append(rt.submit(q_rows[i], w_rows[i], sems[i], ef=ef, k=k,
                                  deadline=rt.clock() + 300.0))
        replies = [f.result(timeout=600) for f in futs]
        stats = rt.stats()
    dt_async = time.perf_counter() - t0
    qps_async = nreq / dt_async
    assert all(w.result(timeout=5) == b_churn for w in wfuts)
    assert stats["rejected"] == 0 and stats["writes"] == 2

    # --- consistency: every async reply == direct search on its pinned
    # snapshot, and async == sync per request (both bitwise)
    pinned_ok = 0
    by_index: dict[int, list[int]] = {}
    for i, r in enumerate(replies):
        by_index.setdefault(id(r.index), []).append(i)
    snapshots = {id(r.index): r.index for r in replies}
    for iid, idxs in by_index.items():
        index = snapshots[iid]
        sel = jnp.asarray(idxs)
        B = len(idxs)
        Bp = bucket_batch_size(B)
        from repro.core import FLAG_IF, as_sem_flags

        q = qv[sel]
        w = qw[sel]
        f = as_sem_flags([sems[i] for i in idxs], B)
        if Bp != B:
            pad = Bp - B
            q = jnp.concatenate([q, jnp.zeros((pad, q.shape[1]), q.dtype)])
            w = jnp.concatenate(
                [w, jnp.broadcast_to(jnp.asarray([2.0, -2.0], w.dtype),
                                     (pad, 2))])
            f = jnp.concatenate([f, jnp.full((pad,), FLAG_IF, jnp.int32)])
        ref = search_mixed(index.store, q, w, f, ef=ef, k=k)
        rids, rdist = np.asarray(ref.ids), np.asarray(ref.dist)
        for j, i in enumerate(idxs):
            if (np.array_equal(replies[i].ids, rids[j])
                    and np.array_equal(replies[i].dist, rdist[j])):
                pinned_ok += 1
    frac_pinned = pinned_ok / nreq
    frac_eq = sum(
        1 for i, r in enumerate(replies)
        if np.array_equal(r.ids, ids_sync[i])
        and np.array_equal(r.dist, dist_sync[i])
    ) / nreq
    assert frac_pinned == 1.0, (
        f"torn read: only {frac_pinned:.3f} of async replies match a direct "
        f"search on their pinned snapshot")
    assert frac_eq == 1.0, (
        f"async/sync divergence: only {frac_eq:.3f} of requests agree")

    # --- recall of each stream half against its own snapshot's truth
    idx_new = eng_async.index
    halves = [("pre", idx0, range(0, mid)), ("post", idx_new, range(mid, nreq))]
    rec = {}
    for name, index, span in halves:
        sel = jnp.asarray(list(span))
        from repro.core.search import SearchResult

        part = SearchResult(
            jnp.asarray(np.stack([replies[i].ids for i in span])),
            jnp.asarray(np.stack([replies[i].dist for i in span])),
            None)
        hit = 0.0
        for s in cycle:
            ssel = [i for i in span if sems[i] is s]
            if not ssel:
                continue
            a = jnp.asarray(ssel)
            gt = index.ground_truth(qv[a], qw[a], sem=s, k=k)
            sub = SearchResult(part.ids[a - sel[0]], part.dist[a - sel[0]], None)
            hit += recall(sub, gt) * len(ssel)
        rec[name] = hit / len(sel)

    ratio = qps_async / qps_sync
    rows = [
        common.row(
            "serve_sync_batched", 1e6 * dt_sync / nreq,
            f"qps={qps_sync:.0f} batch={batch} nreq={nreq} churn={b_churn}"),
        common.row(
            "serve_async_runtime", 1e6 * dt_async / nreq,
            f"qps={qps_async:.0f} qps_ratio={ratio:.2f} "
            f"p50_ms={stats['p50_ms']:.1f} p99_ms={stats['p99_ms']:.1f} "
            f"rejected={stats['rejected']} writes={stats['writes']}"),
        common.row(
            "serve_consistency", 0.0,
            f"recall_vs_pinned_snapshot={frac_pinned:.3f} "
            f"recall_async_eq_sync={frac_eq:.3f} "
            f"recall_pre={rec['pre']:.3f} recall_post={rec['post']:.3f}"),
    ]
    if require_qps_ratio is not None:
        assert ratio >= require_qps_ratio, (
            f"async runtime sustains only {ratio:.2f}x the sync batched "
            f"QPS (need >= {require_qps_ratio}x)")
    return rows


# ---------------------------------------------------------------- kernels
def bench_kernels():
    """Pallas kernels (interpret mode on CPU — relative numbers only) vs jnp."""
    from repro.kernels import ops, ref

    rows = []
    k1, k2, k3, k4 = jax.random.split(jax.random.key(0), 4)
    q = jax.random.normal(k1, (64, 128))
    x = jax.random.normal(k2, (4096, 128))
    oi = jnp.sort(jax.random.uniform(k3, (4096, 2)), axis=1)
    c = jax.random.uniform(k4, (64, 1))
    qi = jnp.concatenate([jnp.maximum(c - 0.3, 0), jnp.minimum(c + 0.3, 1)], axis=1)

    dt, _ = common.timed(lambda: ref.pairwise_sq_dist(q, x))
    rows.append(common.row("kernel_l2dist_jnp_ref", dt * 1e6, "oracle"))
    dt, _ = common.timed(lambda: ops.pairwise_sq_dist(q, x))
    rows.append(common.row("kernel_l2dist_pallas_interp", dt * 1e6,
                           "interpret-mode (TPU target)"))
    dt, _ = common.timed(lambda: ref.filtered_topk(q, x, oi, qi, is_filter=True, k=10))
    rows.append(common.row("kernel_fusedscan_jnp_ref", dt * 1e6, "oracle"))
    dt, _ = common.timed(lambda: ops.filtered_topk(q, x, oi, qi, is_filter=True, k=10))
    rows.append(common.row("kernel_fusedscan_pallas_interp", dt * 1e6,
                           "interpret-mode (TPU target)"))
    idx = jax.random.randint(k3, (64, 32), 0, 4096)
    dt, _ = common.timed(lambda: ref.gather_sq_dist(x, idx, q))
    rows.append(common.row("kernel_gatherdist_jnp_ref", dt * 1e6, "oracle"))
    dt, _ = common.timed(lambda: ops.gather_sq_dist(x, idx, q))
    rows.append(common.row("kernel_gatherdist_pallas_interp", dt * 1e6,
                           "interpret-mode (TPU target)"))
    dt, _ = common.timed(lambda: ops.expand_score(x, idx, q, backend="xla"))
    rows.append(common.row("kernel_expandscore_xla_twin", dt * 1e6,
                           "chunked elementwise twin (bit-identical)"))
    dt, _ = common.timed(lambda: ops.expand_score(x, idx, q, backend="legacy"))
    rows.append(common.row("kernel_expandscore_legacy", dt * 1e6,
                           "(B,C,d) gather + matmul baseline"))
    return rows


# ---------------------------------------------------------------- LM train/serve
def bench_lm_steps():
    """Reduced-config train/serve step times for a few representative archs."""
    from repro.configs.registry import get_arch
    from repro.models.api import get_model
    from repro.train import AdamWConfig, make_train_step, optim

    rows = []
    for arch in ("qwen3-32b", "rwkv6-1.6b", "qwen3-moe-235b-a22b"):
        cfg = get_arch(arch).reduced
        model = get_model(cfg)
        params = model.init(jax.random.key(0))
        ocfg = AdamWConfig(warmup_steps=1, total_steps=8)
        ostate = optim.init(ocfg, params)
        step = make_train_step(model, ocfg, donate=False)
        b = {"tokens": jnp.ones((2, 64), jnp.int32),
             "labels": jnp.ones((2, 64), jnp.int32),
             "mask": jnp.ones((2, 64), jnp.float32)}
        if cfg.family == "encdec":
            b["frames"] = jnp.zeros((2, 32, cfg.d_model))
        dt, _ = common.timed(lambda: step(params, ostate, b), warmup=1, iters=2)
        rows.append(common.row(f"train_step_{arch}_reduced", dt * 1e6,
                               f"tokens/s={2*64/dt:.0f}"))
    return rows
