"""Shared benchmark fixtures: cached corpora, indexes, timing helpers.

Scale note (DESIGN.md §6): the paper's datasets (GIST1M, DB-OpenAI, …) are
not available offline, so the harness runs deterministic synthetic corpora
at CPU scale; every bench is parameterized by n so the identical harness
reproduces paper scale on a pod.  Shapes of QPS-recall curves and relative
orderings are the reproduction target, not absolute C++ QPS.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp

from repro.core import Semantics, UGConfig, UGIndex, recall
from repro.core.baselines import HiPNGLite, PostFilterIndex
from repro.data import CorpusConfig, make_corpus, make_queries

N_DEFAULT = 4000
DIM = 24
NQ = 64

UG_CFG = UGConfig(
    ef_spatial=32, ef_attribute=64, max_edges_if=32, max_edges_is=32,
    iterations=3, repair_width=16, exact_spatial=True, block=1024,
)


@functools.lru_cache(maxsize=8)
def corpus(n: int = N_DEFAULT, dim: int = DIM, seed: int = 0):
    return make_corpus(CorpusConfig(n=n, dim=dim, seed=seed))


@functools.lru_cache(maxsize=8)
def queries(workload: str = "uniform", n: int = N_DEFAULT, dim: int = DIM, nq: int = NQ):
    return make_queries(CorpusConfig(n=n, dim=dim), nq, workload=workload)


EXACT_SPATIAL_CUTOFF = 8192   # above this the n^2 exact pass is dropped


@functools.lru_cache(maxsize=8)
def ug_index(n: int = N_DEFAULT, dim: int = DIM, cfg: UGConfig | None = None) -> UGIndex:
    x, ints = corpus(n, dim)
    if cfg is None:
        cfg = UG_CFG if n <= EXACT_SPATIAL_CUTOFF else dataclasses.replace(
            UG_CFG, exact_spatial=False)   # large-n (run.py --n) path
    return UGIndex.build(x, ints, cfg)


@functools.lru_cache(maxsize=4)
def postfilter_index(n: int = N_DEFAULT, dim: int = DIM) -> PostFilterIndex:
    x, ints = corpus(n, dim)
    return PostFilterIndex.build(x, ints, UG_CFG)


@functools.lru_cache(maxsize=4)
def hipng_index(n: int = N_DEFAULT, dim: int = DIM) -> HiPNGLite:
    x, ints = corpus(n, dim)
    return HiPNGLite.build(x, ints, depth=2, config=UG_CFG)


def timed(fn, *args, warmup: int = 1, iters: int = 3, **kw):
    """(seconds_per_call, result) with jit warmup.

    Blocks on the *whole* result tree: with async dispatch, waiting on a
    single leaf would stop the clock while sibling results (e.g. the other
    per-semantics batches of a split schedule) are still executing.
    """
    out = None
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters, out


def qps_recall(index, qv, qi, *, sem=Semantics.IF, ef=64, k=10):
    """(qps, recall@k) for one index/ef point."""
    dt, res = timed(lambda: index.search(qv, qi, sem=sem, ef=ef, k=k))
    gt = index.ground_truth(qv, qi, sem=sem, k=k)
    return qv.shape[0] / dt, recall(res, gt)


def row(name: str, us_per_call: float, derived: str) -> dict:
    return {"name": name, "us_per_call": us_per_call, "derived": derived}
