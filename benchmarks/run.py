"""Benchmark driver: one function per paper table (DESIGN.md §7).

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` shrinks corpora for
smoke runs; ``--only <prefix>[,<prefix>…]`` filters benches; ``--json PATH``
additionally writes the rows as a JSON artifact — one schema across build,
search and updates benches, the CI perf-trajectory surface.

Perf gate (DESIGN.md §11): ``--check BENCH_baseline.json`` compares the
produced rows against committed thresholds and exits non-zero on a
recall or peak-bytes regression (or a disappeared row);
``--write-baseline PATH`` derives those thresholds from the current run
(recall floor −0.03, peak-bytes ceiling ×1.25).
"""
from __future__ import annotations

import argparse
import json
import re
import sys
import time
import traceback

_METRIC = re.compile(r"(\w+)=([-+]?[0-9]*\.?[0-9]+(?:[eE][-+]?[0-9]+)?)\b")

RECALL_SLACK = 0.03     # committed floor = measured recall − slack
BYTES_HEADROOM = 1.25   # committed ceiling = measured bytes × headroom


def parse_metrics(derived: str) -> dict[str, float]:
    """Extract ``key=value`` numeric metrics from a row's derived column."""
    return {k: float(v) for k, v in _METRIC.findall(derived)}


def gated_metrics(derived: str) -> tuple[dict, dict]:
    """(min-bounded, max-bounded) metrics of one row: recalls are floors,
    byte counts are ceilings.  QPS/latency stay ungated (noisy on shared CI
    runners); recall and traced peak-bytes are deterministic.  Comparison
    yardsticks (``recall_fresh_rebuild``) are not gated — they measure the
    baseline builder, not the code under test."""
    m = parse_metrics(derived)
    mins = {
        k: v for k, v in m.items()
        if k.startswith("recall") and "fresh" not in k
    }
    maxs = {k: v for k, v in m.items() if k.endswith("bytes")}
    return mins, maxs


def write_baseline(rows: list[dict], path: str) -> None:
    base = {}
    for r in rows:
        mins, maxs = gated_metrics(r["derived"])
        if not mins and not maxs:
            continue
        base[r["name"]] = {
            "min": {k: round(max(v - RECALL_SLACK, 0.0), 3) for k, v in mins.items()},
            "max": {k: int(v * BYTES_HEADROOM) for k, v in maxs.items()},
        }
    with open(path, "w") as f:
        json.dump({"schema": 1, "rows": base}, f, indent=2, sort_keys=True)
        f.write("\n")


def check_baseline(rows: list[dict], path: str) -> list[str]:
    """Compare rows against a committed baseline; return violation strings."""
    try:
        with open(path) as f:
            base = json.load(f)["rows"]
    except FileNotFoundError:
        return [f"baseline {path} not found — commit it "
                f"(benchmarks/run.py --write-baseline {path})"]
    by_name = {r["name"]: r for r in rows}
    problems = []
    for name, gate in base.items():
        row = by_name.get(name)
        if row is None:
            problems.append(f"{name}: row missing from this run "
                            f"(bench removed or crashed)")
            continue
        m = parse_metrics(row["derived"])
        for key, floor in gate.get("min", {}).items():
            if key not in m:
                problems.append(f"{name}: metric {key} disappeared")
            elif m[key] < floor:
                problems.append(
                    f"{name}: {key}={m[key]:.3f} below baseline floor {floor}")
        for key, ceil in gate.get("max", {}).items():
            if key not in m:
                problems.append(f"{name}: metric {key} disappeared")
            elif m[key] > ceil:
                problems.append(
                    f"{name}: {key}={m[key]:.0f} above baseline ceiling {ceil}")
    return problems


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny corpora for CI regression output (implies --quick)")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench-name prefixes")
    ap.add_argument("--n", type=int, default=None, dest="n_override",
                    help="override the corpus size for every n-parameterized "
                         "bench (e.g. --n 1000000 --only memory,build pushes "
                         "the plane-frontier and build tables to large n; "
                         "builds above the exact-spatial cutoff go through "
                         "the on-device sharded path)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows to PATH as a JSON artifact")
    ap.add_argument("--check", default=None, metavar="BASELINE",
                    help="fail on recall/peak-bytes regression against a "
                         "committed baseline JSON (the CI perf gate)")
    ap.add_argument("--write-baseline", default=None, metavar="PATH",
                    help="derive and write baseline thresholds from this run")
    args = ap.parse_args(argv)

    from benchmarks import tables

    if args.smoke:
        args.quick = True
    n = (600 if args.smoke else 2000) if args.quick else None
    build_sizes = (400,) if args.smoke else ((800, 1600) if args.quick else (1000, 2000, 4000))
    if args.n_override:
        n = args.n_override
        build_sizes = (args.n_override,)
    benches = [
        ("ifann", lambda: tables.bench_ifann(**({"n": n} if n else {}))),
        ("query_types", lambda: tables.bench_query_types(**({"n": n} if n else {}))),
        ("workloads", lambda: tables.bench_workloads(**({"n": n} if n else {}))),
        ("indexing", lambda: tables.bench_indexing(**({"n": n} if n else {}))),
        ("vary_k", lambda: tables.bench_k(**({"n": n} if n else {}))),
        ("sensitivity", lambda: tables.bench_sensitivity(n=1200 if args.quick else 2000)),
        ("scalability", lambda: tables.bench_scalability(
            sizes=(500, 1000, 2000) if args.quick else (1000, 2000, 4000, 8000))),
        ("beam_sweep", lambda: tables.bench_beam_sweep(**({"n": n} if n else {}))),
        ("mixed_workload", lambda: tables.bench_mixed_workload(
            **({"n": n} if n else {}),
            require_speedup=2.0 if args.smoke else None)),
        ("build", lambda: tables.bench_build(sizes=build_sizes)),
        ("updates", lambda: tables.bench_updates(
            **({"n": n} if n else {}),
            require_recall_gap=0.05 if args.smoke else None)),
        ("memory", lambda: tables.bench_memory(
            **({"n": n} if n else {}),
            require_reduction=3.0 if args.smoke else None)),
        ("serve", lambda: tables.bench_serve(
            **({"n": n} if n else {}),
            require_qps_ratio=0.85 if args.smoke else None)),
        ("kernels", tables.bench_kernels),
        ("lm_steps", tables.bench_lm_steps),
    ]
    only = args.only.split(",") if args.only else None
    print("name,us_per_call,derived")
    failures = 0
    all_rows = []
    for name, fn in benches:
        if only and not any(name.startswith(p) for p in only):
            continue
        t0 = time.time()
        try:
            for r in fn():
                all_rows.append(r)
                print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
            print(f"# {name} done in {time.time()-t0:.0f}s", file=sys.stderr)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {name} FAILED:\n{traceback.format_exc()}", file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(all_rows, f, indent=2)
    if args.write_baseline:
        write_baseline(all_rows, args.write_baseline)
        print(f"# baseline written to {args.write_baseline}", file=sys.stderr)
    if args.check:
        problems = check_baseline(all_rows, args.check)
        for p in problems:
            print(f"# REGRESSION {p}", file=sys.stderr)
        if problems:
            print(f"# perf gate: {len(problems)} regression(s) against "
                  f"{args.check}", file=sys.stderr)
            failures += 1
        else:
            print(f"# perf gate: clean against {args.check}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
