"""Benchmark driver: one function per paper table (DESIGN.md §7).

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` shrinks corpora for
smoke runs; ``--only <prefix>[,<prefix>…]`` filters benches; ``--json PATH``
additionally writes the rows as a JSON artifact (the CI perf-trajectory
surface, e.g. ``BENCH_search.json``).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny corpora for CI regression output (implies --quick)")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench-name prefixes")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows to PATH as a JSON artifact")
    args = ap.parse_args(argv)

    from benchmarks import tables

    if args.smoke:
        args.quick = True
    n = (600 if args.smoke else 2000) if args.quick else None
    build_sizes = (400,) if args.smoke else ((800, 1600) if args.quick else (1000, 2000, 4000))
    benches = [
        ("ifann", lambda: tables.bench_ifann(**({"n": n} if n else {}))),
        ("query_types", lambda: tables.bench_query_types(**({"n": n} if n else {}))),
        ("workloads", lambda: tables.bench_workloads(**({"n": n} if n else {}))),
        ("indexing", lambda: tables.bench_indexing(**({"n": n} if n else {}))),
        ("vary_k", lambda: tables.bench_k(**({"n": n} if n else {}))),
        ("sensitivity", lambda: tables.bench_sensitivity(n=1200 if args.quick else 2000)),
        ("scalability", lambda: tables.bench_scalability(
            sizes=(500, 1000, 2000) if args.quick else (1000, 2000, 4000, 8000))),
        ("beam_sweep", lambda: tables.bench_beam_sweep(**({"n": n} if n else {}))),
        ("mixed_workload", lambda: tables.bench_mixed_workload(
            **({"n": n} if n else {}),
            require_speedup=2.0 if args.smoke else None)),
        ("build", lambda: tables.bench_build(sizes=build_sizes)),
        ("kernels", tables.bench_kernels),
        ("lm_steps", tables.bench_lm_steps),
    ]
    only = args.only.split(",") if args.only else None
    print("name,us_per_call,derived")
    failures = 0
    all_rows = []
    for name, fn in benches:
        if only and not any(name.startswith(p) for p in only):
            continue
        t0 = time.time()
        try:
            for r in fn():
                all_rows.append(r)
                print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
            print(f"# {name} done in {time.time()-t0:.0f}s", file=sys.stderr)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {name} FAILED:\n{traceback.format_exc()}", file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(all_rows, f, indent=2)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
